//! tftune CLI — the launcher for every workflow in the repo.
//!
//! Subcommands:
//!   tune            run one tuning session on the simulated target
//!   serve           run the target-side evaluation daemon (paper Fig. 4)
//!   surrogate-serve host the shared GP factor for a fleet of tuner processes
//!   remote-tune     drive one or more remote target daemons as the host
//!   sweep           Fig. 6 exhaustive sweep (+ findings table)
//!   figures         regenerate paper figures/tables (fig5 fig6 fig7 table1 all)
//!   space           print Table 1 / search-space info
//!   profile         per-op schedule under a configuration
//!   dashboard       live panels / critical-path report over an event stream
//!
//! Flag parsing is in-tree (clap is not vendored in this offline image).

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use tftune::algorithms::Algorithm;
use tftune::config::{SurrogateKind, TuneConfig};
use tftune::evaluator::{Evaluator, Objective, RemoteEvaluator};
use tftune::figures::{fig5, fig6, fig7, tables, OUT_DIR};
use tftune::server::TargetServer;
use tftune::session::{Budget, TuningSession};
use tftune::sim::ModelId;

/// Flags that take no value. Data-driven so adding one is a single entry
/// here rather than a special case inside the parser.
const BOOL_FLAGS: &[&str] = &["fine", "help", "once", "report", "resume", "tune-lengthscale"];

/// Minimal flag parser: `--key value` pairs plus positional args.
struct Args {
    flags: BTreeMap<String, String>,
    positional: Vec<String>,
}

impl Args {
    fn parse(argv: &[String]) -> Result<Args> {
        let mut flags = BTreeMap::new();
        let mut positional = Vec::new();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(key) = a.strip_prefix("--") {
                if BOOL_FLAGS.contains(&key) {
                    flags.insert(key.to_string(), "true".to_string());
                } else {
                    i += 1;
                    let v = argv.get(i).with_context(|| format!("--{key} needs a value"))?;
                    flags.insert(key.to_string(), v.clone());
                }
            } else {
                positional.push(a.clone());
            }
            i += 1;
        }
        Ok(Args { flags, positional })
    }

    fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(String::as_str)
    }

    /// The one parse-with-context helper behind every typed flag: absent
    /// flags yield `None`, present ones must satisfy `parse` or fail with
    /// a uniform "unknown/invalid <what> '<value>'" error.
    fn opt<T>(
        &self,
        key: &str,
        what: &str,
        parse: impl FnOnce(&str) -> Option<T>,
    ) -> Result<Option<T>> {
        match self.get(key) {
            None => Ok(None),
            Some(v) => parse(v)
                .map(Some)
                .with_context(|| format!("unknown {what} '{v}' (from --{key})")),
        }
    }

    /// Like [`Args::opt`] but the flag is mandatory.
    fn req<T>(
        &self,
        key: &str,
        what: &str,
        parse: impl FnOnce(&str) -> Option<T>,
    ) -> Result<T> {
        self.opt(key, what, parse)?
            .with_context(|| format!("--{key} is required"))
    }

    fn usize_or(&self, key: &str, default: usize) -> Result<usize> {
        Ok(self.opt(key, "integer", |v| v.parse().ok())?.unwrap_or(default))
    }

    fn u64_or(&self, key: &str, default: u64) -> Result<u64> {
        Ok(self.opt(key, "integer", |v| v.parse().ok())?.unwrap_or(default))
    }

    fn f64_opt(&self, key: &str) -> Result<Option<f64>> {
        self.opt(key, "number", |v| v.parse().ok())
    }
}

fn usage() -> &'static str {
    "tftune — gradient-free auto-tuning of a TensorFlow-style CPU backend

USAGE: tftune <command> [flags]

COMMANDS
  tune         --model <m> --alg <bo|ga|nms|random|grid> [--iters 50]
               [--seed 0] [--parallel 1] [--max-seconds S]
               [--surrogate native|hlo|sharded] [--objective throughput|latency]
               [--objectives spec] [--scalarize weighted:<w,..>|smsego]
               [--surrogate-addr host:port] [--tune-lengthscale]
               [--score-threads N] [--score-tier f64|f32]
               [--shard-cap 512] [--blend-k 2]
               [--state-dir DIR] [--resume] [--events-file events.jsonl]
               [--out hist.jsonl] [--config run.json]
  serve        --model <m> [--addr 127.0.0.1:7070] [--seed 0]
  surrogate-serve  [--addr 127.0.0.1:7071] [--objectives spec]
               [--state-dir DIR] [--fsync-every 1] [--snapshot-every 30]
               [--max-spaces 16] [--space-idle-secs S]
               [--max-rows-per-space N] [--surrogate auto|exact|sharded]
               [--shard-cap 512] [--blend-k 2]
               [--events-addr 127.0.0.1:7072] [--events-file events.jsonl]
               host the authoritative shared GP factors: tuner processes
               started with --surrogate-addr condition the model whose
               search-space fingerprint their hello declares
  remote-tune  --addr <host:port[,host:port...]> --model <m> --alg <a>
               [--iters 50] [--seed 0] [--parallel N] [--max-seconds S]
               [--surrogate-addr host:port] [--objectives spec]
               [--scalarize weighted:<w,..>|smsego]
  sweep        [--fine] [--out-dir figures_out]   (Fig. 6)
  figures      <fig5|fig6|fig7|table1|table2|all> [--iters 50]
               [--seeds 0,1,2] [--surrogate native|hlo] [--out-dir figures_out]
  space        [--model <m>]                      (Table 1)
  profile      --model <m> [--inter 1 --intra 14 --batch 256 --blocktime 0
               --omp 24]   (per-op schedule under a configuration)
  dashboard    --events-file events.jsonl | --events-addr host:port
               [--refresh-ms 500] [--once] [--max-seconds S] [--report]

PARALLELISM
  tune --parallel N measures N trials concurrently on N simulator
  evaluators (N=1 reproduces the serial loop exactly); remote-tune shards
  trials across every daemon address given in --addr.

SCORING ENGINE (BO only)
  --score-threads N partitions each candidate panel across N threads;
  proposals are bit-identical to serial for any N. --score-tier f32
  ranks candidates in single precision (faster panels, same argmax on
  well-separated gains); the default f64 tier is the pinned oracle.

SCALING TIER (BO only)
  tune --surrogate sharded swaps the flat exact GP for a KD-sharded
  ensemble: observations split into locally-exact shards of at most
  --shard-cap rows, so a tell costs O(cap²) no matter how long the run,
  and each proposal blends the --blend-k nearest shards' posteriors
  (variance-weighted product of experts). --shard-cap >= n keeps one
  shard and is bit-identical to --surrogate native. On the daemon,
  surrogate-serve --max-rows-per-space N caps each hosted space: at the
  cap the space's factor converts to the sharded tier in place (the
  default --surrogate auto), stays sharded from the first row with
  --surrogate sharded, or refuses further tells with a typed error
  under --surrogate exact.

CROSS-PROCESS SURROGATE
  Start `surrogate-serve` once, then give every BO tuner process
  --surrogate-addr <its address>: all their measurements condition one
  served GP factor, and each process's in-flight trials are leased to the
  others as constant-liar fantasies (expiring if a process dies).

FLEET SERVICE
  One daemon serves many search spaces at once: each tuner's hello
  carries its space's fingerprint (printed by `tune`), and the daemon
  keys an independent factor per fingerprint, creating spaces lazily up
  to --max-spaces and answering a mismatched hello with a typed
  hello-err. --space-idle-secs S evicts spaces idle for S seconds
  (snapshotting them first when --state-dir is set; a later hello
  restores the space bit-identically from its space-<fingerprint>/
  namespace).

DURABILITY
  surrogate-serve --state-dir DIR journals every tell/set-hyper to a
  write-ahead log and checkpoints snapshots in the background; on
  restart the daemon restores the served factor bit-identically and
  replicas reconnect and re-publish their leases. tune --state-dir DIR
  streams every completed trial to DIR/session.jsonl; add --resume to
  continue an interrupted run's remaining budget instead of starting
  cold. See ARCHITECTURE.md, section "Durability".

OBSERVABILITY
  tune --events-file P streams every session event (trial lifecycle,
  surrogate queue drains, Pareto-front advances, sync round trips) as one
  JSON line each; surrogate-serve --events-addr additionally publishes
  the daemon's stream over TCP to any number of subscribers. Emission is
  non-blocking: a slow or absent consumer never stalls a tell/ask, the
  bus instead counts drops (reported at shutdown). `tftune dashboard`
  renders live panels from either source; --report reads a finished
  events file and prints the critical-path accounting (evaluator wait vs
  surrogate lock vs wire vs acquisition). See ARCHITECTURE.md, section
  "The observability plane".

MULTI-OBJECTIVE
  --objectives declares what a BO run optimises: the primary objective
  plus named Measurement metadata columns, ':min' to minimise — e.g.
  --objectives throughput,p99_latency_ms:min. The GP scores every
  objective in one panel pass over one factor; --scalarize picks the
  acquisition (weighted:<w,..> fixed weights, or smsego hypervolume
  gain over the non-dominated front). The history records each trial's
  objective vector, so Pareto fronts are readable from the JSONL.

MODELS
  ssd-mobilenet resnet50-fp32 resnet50-int8 transformer-lt bert ncf
ALGORITHMS
  bo ga nms random grid sa coord"
}

fn parse_model(args: &Args) -> Result<ModelId> {
    args.req("model", "model", ModelId::parse)
        .context("see `tftune space` for models")
}

fn parse_alg(args: &Args) -> Result<Algorithm> {
    args.req("alg", "algorithm", Algorithm::parse)
}

fn parse_surrogate(args: &Args) -> Result<SurrogateKind> {
    Ok(args
        .opt("surrogate", "surrogate", SurrogateKind::parse)?
        .unwrap_or(SurrogateKind::Native))
}

fn parse_seeds(args: &Args, default: &[u64]) -> Result<Vec<u64>> {
    match args.get("seeds") {
        None => Ok(default.to_vec()),
        Some(s) => s
            .split(',')
            .map(|x| x.trim().parse::<u64>().context("bad --seeds"))
            .collect(),
    }
}

/// Budget shared by `tune` and `remote-tune`: iteration cap + optional
/// wall-clock limit.
fn parse_budget(iters: usize, args: &Args) -> Result<Budget> {
    let mut budget = Budget::evaluations(iters);
    if let Some(s) = args.f64_opt("max-seconds")? {
        budget = budget.with_max_seconds(s);
    }
    Ok(budget)
}

fn cmd_tune(args: &Args) -> Result<()> {
    let mut cfg = match args.get("config") {
        Some(path) => TuneConfig::load(Path::new(path))?,
        None => TuneConfig::default(),
    };
    if args.get("model").is_some() {
        cfg.model = parse_model(args)?;
    }
    if args.get("alg").is_some() {
        cfg.algorithm = parse_alg(args)?;
    }
    cfg.iterations = args.usize_or("iters", cfg.iterations)?;
    cfg.seed = args.u64_or("seed", cfg.seed)?;
    cfg.parallel = args.usize_or("parallel", cfg.parallel)?;
    anyhow::ensure!(cfg.parallel >= 1, "--parallel must be at least 1");
    if let Some(s) = args.f64_opt("max-seconds")? {
        cfg.max_seconds = Some(s);
    }
    if args.get("surrogate").is_some() {
        cfg.surrogate = parse_surrogate(args)?;
    }
    if let Some(out) = args.get("out") {
        cfg.history_out = Some(PathBuf::from(out));
    }
    if let Some(o) = args.opt("objective", "objective", Objective::parse)? {
        cfg.objective = o;
    }
    if let Some(addr) = args.get("surrogate-addr") {
        cfg.surrogate_addr = Some(addr.to_string());
    }
    if args.get("tune-lengthscale").is_some() {
        cfg.tune_lengthscale = true;
    }
    cfg.score_threads = args.usize_or("score-threads", cfg.score_threads)?;
    anyhow::ensure!(cfg.score_threads >= 1, "--score-threads must be at least 1");
    if let Some(t) = args.opt("score-tier", "score tier", tftune::gp::ScoreTier::parse)? {
        cfg.score_tier = t;
    }
    cfg.shard_cap = args.usize_or("shard-cap", cfg.shard_cap)?;
    anyhow::ensure!(cfg.shard_cap >= 1, "--shard-cap must be at least 1");
    cfg.blend_k = args.usize_or("blend-k", cfg.blend_k)?;
    anyhow::ensure!(cfg.blend_k >= 1, "--blend-k must be at least 1");
    if let Some(spec) = args.get("objectives") {
        cfg.objectives =
            Some(tftune::ObjectiveSet::parse(spec).map_err(|e| anyhow::anyhow!(e))?);
    }
    if let Some(spec) = args.get("scalarize") {
        cfg.scalarize =
            Some(tftune::Scalarization::parse(spec).map_err(|e| anyhow::anyhow!(e))?);
    }
    if let Some(dir) = args.get("state-dir") {
        cfg.state_dir = Some(PathBuf::from(dir));
    }
    if let Some(p) = args.get("events-file") {
        cfg.events_file = Some(PathBuf::from(p));
    }
    if args.get("resume").is_some() {
        cfg.resume = true;
    }
    if cfg.resume {
        let dir = cfg.state_dir.as_ref().context("--resume requires --state-dir")?;
        let log = dir.join(tftune::config::SESSION_LOG);
        let done = if log.exists() {
            tftune::History::load(&log, &cfg.model.space())?.len()
        } else {
            0
        };
        println!(
            "resuming from {}: {done} completed trial(s), {} of {} iteration(s) remaining",
            log.display(),
            cfg.iterations.saturating_sub(done),
            cfg.iterations
        );
    }

    println!(
        "tuning {} with {} for {} iterations (seed {}, parallel {}, surrogate {}, objective {})",
        cfg.model.name(),
        cfg.algorithm.name(),
        cfg.iterations,
        cfg.seed,
        cfg.parallel,
        cfg.surrogate.name(),
        cfg.objective.name()
    );
    {
        // The fleet identity this run presents to a surrogate service: a
        // v4 daemon keys its served factor by this fingerprint.
        let space = cfg.model.space();
        println!(
            "search space {:016x} ({} parameter(s))",
            space.fingerprint(),
            space.dim()
        );
    }
    let history = cfg.run()?;
    let best = history.best().context("empty history")?;
    println!(
        "best {}: {:.2} {} at iteration {}",
        cfg.objective.name(),
        best.value,
        cfg.objective.unit(),
        best.iteration
    );
    let space = cfg.model.space();
    println!("best config: {}", space.config_to_json(&best.config));
    if let Some(set) = &cfg.objectives {
        let front = history.pareto_front();
        println!(
            "non-dominated front over [{}]: {} of {} trials",
            set.spec(),
            front.len(),
            history.len()
        );
    }
    if let Some(p) = &cfg.history_out {
        println!("history written to {}", p.display());
    }
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    let model = parse_model(args)?;
    let addr = args.get("addr").unwrap_or("127.0.0.1:7070");
    let seed = args.u64_or("seed", 0)?;
    let space = model.space();
    let server = TargetServer::bind(
        addr,
        space,
        Box::new(tftune::evaluator::SimEvaluator::new(model, seed)),
    )?;
    println!("target daemon serving sim:{} on {}", model.name(), server.local_addr()?);
    let served = server.serve()?;
    println!("daemon shut down after {served} evaluations");
    Ok(())
}

fn cmd_surrogate_serve(args: &Args) -> Result<()> {
    let addr = args.get("addr").unwrap_or("127.0.0.1:7071");
    let state_dir = args.get("state-dir").map(PathBuf::from);
    let fsync_every = args.usize_or("fsync-every", 1)?;
    let max_spaces = args.usize_or("max-spaces", 16)?;
    let idle_secs = args.f64_opt("space-idle-secs")?;
    if let Some(s) = idle_secs {
        anyhow::ensure!(s > 0.0, "--space-idle-secs must be positive seconds");
    }
    let max_rows = args.opt("max-rows-per-space", "integer", |v| v.parse::<usize>().ok())?;
    if let Some(n) = max_rows {
        anyhow::ensure!(n >= 1, "--max-rows-per-space must be at least 1");
    }
    let tier = args
        .opt("surrogate", "factor tier", tftune::server::FactorTier::parse)?
        .unwrap_or(tftune::server::FactorTier::Auto);
    let shard_cap = args.usize_or("shard-cap", tftune::gp::DEFAULT_SHARD_CAP)?;
    anyhow::ensure!(shard_cap >= 1, "--shard-cap must be at least 1");
    let blend_k = args.usize_or("blend-k", tftune::gp::DEFAULT_BLEND_K)?;
    anyhow::ensure!(blend_k >= 1, "--blend-k must be at least 1");

    // With --state-dir the served factor is durable: recover whatever a
    // previous daemon left behind (bit-identical snapshot + WAL replay),
    // journal every mutation from here on, and checkpoint periodically in
    // the background, off the model lock.
    let (server, factor, persistence) = match &state_dir {
        Some(dir) => {
            let recovered = tftune::persist::recover(dir, tftune::gp::GpHyper::default())?;
            if !recovered.surrogate.is_empty() {
                println!(
                    "restored {} observation(s) from {} (snapshot seq {}, {} WAL \
                     record(s) replayed)",
                    recovered.surrogate.len(),
                    dir.display(),
                    recovered
                        .snapshot_seq
                        .map_or("none".to_string(), |s| s.to_string()),
                    recovered.replayed
                );
            }
            let persistence = tftune::persist::attach(
                &recovered.surrogate,
                dir,
                tftune::persist::PersistOptions { fsync_every },
            )?;
            let (server, factor) =
                TargetServer::bind_surrogate_with(addr, recovered.surrogate)?;
            (server, factor, Some(std::sync::Arc::new(persistence)))
        }
        None => {
            let (server, factor) =
                TargetServer::bind_surrogate_only(addr, tftune::gp::GpHyper::default())?;
            (server, factor, None)
        }
    };
    // Fleet plane: every space beyond the default is keyed by the
    // fingerprint its tuners declare, created lazily up to --max-spaces,
    // and (with --state-dir) journaled under its own space-<fp>/
    // namespace — the boot recovery above only covers the default space;
    // with_fleet_options re-opens the namespaced ones.
    let server = server.with_fleet_options(tftune::server::FleetOptions {
        max_spaces,
        idle_ttl: idle_secs.map(std::time::Duration::from_secs_f64),
        state_dir: state_dir.clone(),
        fsync_every,
        default_hyper: tftune::gp::GpHyper::default(),
        max_rows_per_space: max_rows,
        tier,
        shard_cap,
        blend_k,
    })?;
    // Observability plane: one bus feeds every sink, and the daemon only
    // pays for clock reads / encoding when at least one sink is attached.
    // The publisher handle must outlive serve() — dropping it closes the
    // accept loop and every subscriber.
    let events = if args.get("events-file").is_some() || args.get("events-addr").is_some() {
        Some(tftune::obs::EventBus::new())
    } else {
        None
    };
    let mut publisher = None;
    if let Some(bus) = &events {
        if let Some(path) = args.get("events-file") {
            bus.attach(Box::new(tftune::obs::FileSink::create(Path::new(path))?));
        }
        if let Some(addr) = args.get("events-addr") {
            let p = tftune::obs::EventPublisher::bind(addr, bus)?;
            println!("event stream on {} (line-delimited JSON, subscribe to tail)", p.addr());
            publisher = Some(p);
        }
    }
    let server = match &events {
        Some(bus) => {
            if let Some(p) = &persistence {
                p.set_event_source(bus.source("persist"));
            }
            server.with_events(bus.source("daemon"))
        }
        None => server,
    };
    println!(
        "surrogate service hosting the shared GP factor on {} (protocol v{})",
        server.local_addr()?,
        tftune::server::proto::PROTOCOL_VERSION
    );
    println!(
        "fleet: up to {max_spaces} search space(s){}",
        match idle_secs {
            Some(s) => format!(", idle spaces evicted after {s}s"),
            None => String::new(),
        }
    );
    match (tier, max_rows) {
        (tftune::server::FactorTier::Sharded, cap) => println!(
            "factor tier: sharded from the first row (shard cap {shard_cap}, blend {blend_k}){}",
            cap.map_or(String::new(), |n| format!(", row cap {n} per space")),
        ),
        (tftune::server::FactorTier::Exact, Some(n)) => println!(
            "factor tier: exact, refusing tells beyond {n} row(s) per space"
        ),
        (tftune::server::FactorTier::Auto, Some(n)) => println!(
            "factor tier: exact until {n} row(s) per space, then sharded \
             (shard cap {shard_cap}, blend {blend_k})"
        ),
        _ => {}
    }
    if let Some(p) = &persistence {
        let every = args.f64_opt("snapshot-every")?.unwrap_or(30.0);
        anyhow::ensure!(every > 0.0, "--snapshot-every must be positive seconds");
        println!(
            "durable state in {} (WAL fsync every {} record(s), snapshot every {every}s)",
            p.dir().display(),
            args.usize_or("fsync-every", 1)?
        );
        // Detached checkpoint thread: snapshots only when the store grew,
        // and dies with the process (the WAL alone already recovers the
        // tail; the final snapshot below covers clean shutdown).
        let p = std::sync::Arc::clone(p);
        let snap_factor = factor.clone();
        std::thread::spawn(move || {
            let mut last = snap_factor.total_observations();
            loop {
                std::thread::sleep(std::time::Duration::from_secs_f64(every));
                let now = snap_factor.total_observations();
                if now == last {
                    continue;
                }
                match p.snapshot(&snap_factor) {
                    Ok(seq) => last = now.max(seq),
                    Err(e) => eprintln!("tftune: background snapshot failed: {e}"),
                }
            }
        });
    }
    if let Some(spec) = args.get("objectives") {
        // The served store accepts whatever objective columns arrive;
        // the declaration here is validated and echoed so operators see
        // what the fleet is expected to tune.
        let set = tftune::ObjectiveSet::parse(spec).map_err(|e| anyhow::anyhow!(e))?;
        println!(
            "serving a {}-objective fleet [{}]: v3 tuners contribute all columns, \
             v2 tuners degrade to the primary objective",
            set.k(),
            set.spec()
        );
    }
    println!("attach tuners with: tftune tune --alg bo --surrogate-addr <this address> ...");
    server.serve()?;
    if let Some(p) = &persistence {
        // Clean shutdown: one final snapshot so the next boot replays no
        // WAL suffix at all.
        let seq = p.snapshot(&factor)?;
        println!("final snapshot written at seq {seq}");
    }
    if let Some(bus) = &events {
        bus.flush();
        if bus.dropped() > 0 {
            eprintln!(
                "tftune: {} event(s) dropped by slow sinks (see --events-* docs)",
                bus.dropped()
            );
        }
    }
    if let Some(mut p) = publisher {
        p.stop();
    }
    println!("surrogate service shut down");
    Ok(())
}

fn cmd_dashboard(args: &Args) -> Result<()> {
    use tftune::obs::dashboard::{critical_path, follow_file, follow_socket, DashOptions};

    let file = args.get("events-file");
    let addr = args.get("events-addr");
    anyhow::ensure!(
        file.is_some() != addr.is_some(),
        "dashboard needs exactly one event source: --events-file PATH or --events-addr HOST:PORT"
    );
    if args.get("report").is_some() {
        // Post-hoc critical-path accounting is a whole-stream computation,
        // so it reads a finished file rather than tailing a socket.
        let path = file.context("--report reads a completed run: use --events-file")?;
        let records = tftune::obs::read_events_file(Path::new(path))?;
        anyhow::ensure!(!records.is_empty(), "no events in {path}");
        print!("{}", critical_path(&records).render());
        return Ok(());
    }
    let opts = DashOptions {
        refresh_ms: args.u64_or("refresh-ms", 500)?,
        once: args.get("once").is_some(),
        max_seconds: args.f64_opt("max-seconds")?,
    };
    let mut out = std::io::stdout();
    match (file, addr) {
        (Some(path), None) => follow_file(Path::new(path), &opts, &mut out)?,
        (None, Some(addr)) => {
            follow_socket(addr, &opts, &mut out)?;
        }
        _ => unreachable!("guarded by the exactly-one ensure above"),
    }
    Ok(())
}

fn cmd_remote_tune(args: &Args) -> Result<()> {
    let model = parse_model(args)?;
    let alg = parse_alg(args)?;
    let addrs = args.get("addr").context("--addr is required")?;
    let iters = args.usize_or("iters", 50)?;
    let seed = args.u64_or("seed", 0)?;
    let space = model.space();

    let remotes = RemoteEvaluator::connect_all(addrs, &space)?;
    if let Some(parallel) = args.opt("parallel", "integer", |v| v.parse::<usize>().ok())? {
        anyhow::ensure!(
            parallel == remotes.len(),
            "--parallel {} but {} daemon address(es) given; remote parallelism \
             is one in-flight trial per address in --addr",
            parallel,
            remotes.len()
        );
    }
    for r in &remotes {
        println!("connected to {}", r.describe());
    }
    let pool: Vec<Box<dyn tftune::evaluator::Evaluator + Send>> = remotes
        .into_iter()
        .map(|r| Box::new(r) as Box<dyn tftune::evaluator::Evaluator + Send>)
        .collect();

    // With --surrogate-addr the BO engine conditions a replica of the
    // served factor: every remote-tune process given the same address
    // shares one model. --objectives switches the engine to the declared
    // multi-objective acquisition (BO only, like the service attachment).
    let objectives = match args.get("objectives") {
        Some(spec) => Some(tftune::ObjectiveSet::parse(spec).map_err(|e| anyhow::anyhow!(e))?),
        None => None,
    };
    let scalarize = match args.get("scalarize") {
        Some(spec) => {
            let set = objectives
                .as_ref()
                .context("--scalarize requires --objectives")?;
            Some(
                tftune::Scalarization::parse(spec)
                    .and_then(|s| s.resolve(set.k()))
                    .map_err(|e| anyhow::anyhow!(e))?,
            )
        }
        None => None,
    };
    let surrogate_addr = args.get("surrogate-addr");
    let tuner: Box<dyn tftune::algorithms::Tuner + Send> =
        if surrogate_addr.is_some() || objectives.is_some() {
            anyhow::ensure!(
                alg == Algorithm::Bo,
                "--surrogate-addr/--objectives apply to the BO engine only (got {})",
                alg.name()
            );
            let mut bo = tftune::algorithms::BayesOpt::new(space.clone(), seed);
            if let Some(addr) = surrogate_addr {
                let replica = tftune::gp::RemoteSurrogate::connect_space(addr, &space)
                    .with_context(|| format!("attaching surrogate service {addr}"))?;
                println!(
                    "conditioning space {:016x} of the surrogate service at {addr}",
                    space.fingerprint()
                );
                bo = bo.with_shared_surrogate(replica);
            }
            if let Some(set) = &objectives {
                let scal = match scalarize.clone() {
                    Some(s) => s,
                    None => tftune::Scalarization::Weighted(Vec::new())
                        .resolve(set.k())
                        .map_err(|e| anyhow::anyhow!(e))?,
                };
                println!("optimising objectives [{}] with {}", set.spec(), scal.spec());
                bo = bo.with_objectives(set.clone(), scal);
            }
            Box::new(bo)
        } else {
            alg.build(&space, seed)
        };
    let mut session = TuningSession::new(tuner, pool, parse_budget(iters, args)?);
    if let Some(set) = objectives.clone() {
        session = session.with_objectives(set);
    }
    let history = session.run()?;
    let best = history.best().context("empty history")?;
    println!("best throughput: {:.2} examples/s", best.value);
    println!("best config: {}", space.config_to_json(&best.config));
    if objectives.is_some() {
        println!(
            "non-dominated front: {} of {} trials",
            history.pareto_front().len(),
            history.len()
        );
    }
    if let Some(reason) = session.stop_reason() {
        println!(
            "stopped by {} after {} evaluations ({:.2}s measurement time)",
            reason.name(),
            history.len(),
            history.total_cost_s()
        );
    }
    Ok(())
}

fn cmd_sweep(args: &Args) -> Result<()> {
    let fine = args.get("fine").is_some();
    let out_dir = PathBuf::from(args.get("out-dir").unwrap_or(OUT_DIR));
    let t0 = std::time::Instant::now();
    let points = fig6::run_sweep(ModelId::Resnet50Int8, fine);
    let secs = t0.elapsed().as_secs_f64();
    let findings = fig6::analyze(&points);
    fig6::print_findings(&findings);
    println!("sweep of {} points took {secs:.2}s here", points.len());
    let path = fig6::write_csv(&points, &out_dir)?;
    println!("csv written to {}", path.display());
    Ok(())
}

fn cmd_figures(args: &Args) -> Result<()> {
    let what = args.positional.get(1).map(String::as_str).unwrap_or("all");
    let iters = args.usize_or("iters", 50)?;
    let seeds = parse_seeds(args, &[0, 1, 2])?;
    let surrogate = parse_surrogate(args)?;
    let out_dir = PathBuf::from(args.get("out-dir").unwrap_or(OUT_DIR));

    if matches!(what, "table1" | "all") {
        tables::print_table1();
        tables::print_space_sizes();
    }
    if matches!(what, "fig5" | "all") {
        let curves = fig5::run_figure(iters, &seeds, surrogate, &out_dir)?;
        fig5::print_summary(&curves);
        println!("fig5 CSVs written under {}", out_dir.display());
    }
    if matches!(what, "fig6" | "all") {
        let points = fig6::run_sweep(ModelId::Resnet50Int8, false);
        fig6::print_findings(&fig6::analyze(&points));
        fig6::write_csv(&points, &out_dir)?;
    }
    if matches!(what, "fig7" | "table2" | "all") {
        let samples = fig7::run_samples(iters, seeds[0], surrogate)?;
        fig7::write_csv(&samples, &out_dir)?;
        fig7::print_table2(&samples);
        println!("fig7 CSVs written under {}", out_dir.display());
    }
    Ok(())
}

fn cmd_space(args: &Args) -> Result<()> {
    tables::print_table1();
    if let Some(model) = args.opt("model", "model", ModelId::parse)? {
        let space = model.space();
        println!("\n{}: {} grid points", model.name(), space.size());
        for p in &space.params {
            println!(
                "  {:<32} [{}, {}] step {} ({} values)",
                p.name,
                p.min,
                p.max,
                p.step,
                p.n_values()
            );
        }
    } else {
        tables::print_space_sizes();
    }
    Ok(())
}

fn cmd_profile(args: &Args) -> Result<()> {
    let model = parse_model(args)?;
    let space = model.space();
    let cfg = space.snap(&vec![
        args.u64_or("inter", 1)? as i64,
        args.u64_or("intra", 14)? as i64,
        args.u64_or("batch", space.params[2].min as u64)? as i64,
        args.u64_or("blocktime", 0)? as i64,
        args.u64_or("omp", 24)? as i64,
    ]);
    let workload = tftune::sim::SimWorkload::noiseless(model);
    let report = workload.report(&cfg);
    println!("profile of {} under {}", model.name(), space.config_to_json(&cfg));
    println!(
        "latency {:.3} ms  throughput {:.1} ex/s  peak thread demand {:.0}\n",
        report.latency_s * 1e3,
        report.throughput,
        report.peak_demand
    );
    println!("{:<24} {:>10} {:>10} {:>8} {:>9}  timeline", "op", "start(us)", "dur(us)", "threads", "slowdown");
    let width = 44usize;
    for ev in &report.trace {
        let s = (ev.start_s / report.latency_s * width as f64) as usize;
        let e = ((ev.end_s / report.latency_s * width as f64) as usize).max(s + 1);
        let bar: String = (0..width)
            .map(|i| if i >= s && i < e.min(width) { '#' } else { '.' })
            .collect();
        println!(
            "{:<24} {:>10.1} {:>10.1} {:>8.0} {:>9.2}  {bar}",
            ev.op,
            ev.start_s * 1e6,
            (ev.end_s - ev.start_s) * 1e6,
            ev.threads,
            ev.slowdown
        );
    }
    Ok(())
}

fn main() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if argv.is_empty() {
        println!("{}", usage());
        return Ok(());
    }
    let args = Args::parse(&argv)?;
    if args.get("help").is_some() {
        println!("{}", usage());
        return Ok(());
    }
    match args.positional.first().map(String::as_str) {
        Some("tune") => cmd_tune(&args),
        Some("serve") => cmd_serve(&args),
        Some("surrogate-serve") => cmd_surrogate_serve(&args),
        Some("remote-tune") => cmd_remote_tune(&args),
        Some("sweep") => cmd_sweep(&args),
        Some("figures") => cmd_figures(&args),
        Some("space") => cmd_space(&args),
        Some("profile") => cmd_profile(&args),
        Some("dashboard") => cmd_dashboard(&args),
        Some(other) => bail!("unknown command '{other}'\n\n{}", usage()),
        None => {
            println!("{}", usage());
            Ok(())
        }
    }
}
