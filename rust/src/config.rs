//! Run-spec configuration: a JSON description of a tuning run (model,
//! algorithm, budget, seeds, surrogate backend, output locations), loadable
//! from a file or assembled from CLI flags. Every launcher entry point
//! (CLI, benches, examples) goes through this, so runs are reproducible
//! from a single artifact.

use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::algorithms::Algorithm;
use crate::sim::ModelId;
use crate::util::json::{parse, Json};

/// Which GP surrogate backs the BO engine.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SurrogateKind {
    /// Exact native-Rust GP (no artifacts needed).
    Native,
    /// The AOT HLO artifact via PJRT (production path).
    Hlo,
    /// The sharded scaling tier ([`crate::gp::ShardedGp`]): locally-exact
    /// shards under a KD router, O(`shard_cap`²) per tell regardless of
    /// history length. For long campaigns where the exact engine's O(n²)
    /// append becomes the bottleneck.
    Sharded,
}

impl SurrogateKind {
    pub fn parse(s: &str) -> Option<SurrogateKind> {
        match s.to_lowercase().as_str() {
            // "exact" names the flat engine in the sharded-tier docs and
            // CLI (`--surrogate exact|sharded`); it is the same native GP.
            "native" | "exact" => Some(SurrogateKind::Native),
            "hlo" | "pjrt" | "artifact" => Some(SurrogateKind::Hlo),
            "sharded" => Some(SurrogateKind::Sharded),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            SurrogateKind::Native => "native",
            SurrogateKind::Hlo => "hlo",
            SurrogateKind::Sharded => "sharded",
        }
    }
}

/// A complete tuning-run specification.
#[derive(Debug, Clone)]
pub struct TuneConfig {
    pub model: ModelId,
    pub algorithm: Algorithm,
    /// Evaluation budget (the paper caps at 50).
    pub iterations: usize,
    pub seed: u64,
    /// Measurement-noise sigma for the simulated target.
    pub noise_sigma: f64,
    pub surrogate: SurrogateKind,
    /// What the tuner maximises (throughput or inverse latency).
    pub objective: crate::evaluator::Objective,
    /// Simulator evaluators measuring in parallel (1 = exact serial loop).
    pub parallel: usize,
    /// Optional wall-clock limit wired into the session `Budget`.
    pub max_seconds: Option<f64>,
    /// Where to write the history JSONL (None = don't persist).
    pub history_out: Option<PathBuf>,
    /// Address of a surrogate service (`surrogate-serve`) to condition
    /// against: the BO engine attaches a `RemoteSurrogate` replica, so
    /// several tuner processes share one factor. BO only.
    pub surrogate_addr: Option<String>,
    /// Re-select the GP lengthscale by log marginal likelihood as history
    /// grows (`BayesOpt::with_lengthscale_selection`). Drives the native
    /// stack *and* the AOT HLO artifact — the artifact takes lengthscale
    /// as a runtime input, so no recompilation is involved. BO only.
    pub tune_lengthscale: bool,
    /// Declared multi-objective set (`--objectives throughput,p99:min`):
    /// primary `value` plus named `Measurement::metadata` columns. BO +
    /// native surrogate only; drives both the engine's acquisition and
    /// the history's recorded objective vectors.
    pub objectives: Option<crate::objectives::ObjectiveSet>,
    /// Acquisition scalarisation for a multi-objective run
    /// (`--scalarize weighted:0.7,0.3` or `smsego`). Defaults to equal
    /// weights over the declared objectives.
    pub scalarize: Option<crate::objectives::Scalarization>,
    /// Durable-run state directory (`--state-dir`): every completed trial
    /// is streamed to `DIR/session.jsonl` (append + fsync) as it lands,
    /// so an interrupted run leaves a resumable record on disk.
    pub state_dir: Option<PathBuf>,
    /// Continue an interrupted durable run (`--resume`): prior trials in
    /// `state_dir/session.jsonl` are loaded, warm-started into the
    /// engine, and counted against `iterations` — the run finishes the
    /// remaining budget instead of starting cold. Requires `state_dir`.
    pub resume: bool,
    /// Threads the BO scoring engine partitions each candidate panel
    /// across (`--score-threads`). Results are bit-identical to serial
    /// for any value; 1 = the plain serial loop. BO only.
    pub score_threads: usize,
    /// Precision tier for acquisition ranking (`--score-tier`): `f64`
    /// (default, the pinned oracle) or `f32` (fast ranking tier; means
    /// and stds are computed in single precision and cast up). BO only.
    pub score_tier: crate::gp::ScoreTier,
    /// Leaf capacity of the sharded surrogate tier (`--shard-cap`): a
    /// shard splits when it exceeds this many rows, so a tell costs
    /// O(cap²) regardless of total history. Meaningful with
    /// `surrogate: sharded`; `shard_cap >= n` keeps a single shard,
    /// which is bit-identical to the exact engine.
    pub shard_cap: usize,
    /// Blend neighbourhood of the sharded tier (`--blend-k`): each
    /// candidate is scored by its owning shard plus this-many-minus-one
    /// nearest shards, combined product-of-experts style. 1 = pure
    /// routing (owner only).
    pub blend_k: usize,
    /// Observability event stream (`--events-file`): every structured
    /// event the run emits — trial lifecycle, ask batches, surrogate
    /// drains, Pareto/hypervolume advances, sync/lease traffic — is
    /// appended to this JSONL file (see `obs`). `tftune dashboard
    /// --events-file F` tails it live; `--report` post-processes it into
    /// critical-path accounting. None = the plane stays disabled and the
    /// hot paths skip event construction entirely.
    pub events_file: Option<PathBuf>,
}

/// File inside a `--state-dir` holding the streamed per-trial session
/// journal (one [`crate::history::Evaluation`] JSONL line per completed
/// trial, append order = completion order).
pub const SESSION_LOG: &str = "session.jsonl";

impl Default for TuneConfig {
    fn default() -> Self {
        TuneConfig {
            model: ModelId::Resnet50Int8,
            algorithm: Algorithm::Bo,
            iterations: 50,
            seed: 0,
            noise_sigma: crate::sim::noise::DEFAULT_SIGMA,
            surrogate: SurrogateKind::Native,
            objective: crate::evaluator::Objective::Throughput,
            parallel: 1,
            max_seconds: None,
            history_out: None,
            surrogate_addr: None,
            tune_lengthscale: false,
            objectives: None,
            scalarize: None,
            state_dir: None,
            resume: false,
            score_threads: 1,
            score_tier: crate::gp::ScoreTier::F64,
            shard_cap: crate::gp::DEFAULT_SHARD_CAP,
            blend_k: crate::gp::DEFAULT_BLEND_K,
            events_file: None,
        }
    }
}

impl TuneConfig {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("model", self.model.short_name().into()),
            ("algorithm", self.algorithm.name().into()),
            ("iterations", self.iterations.into()),
            ("seed", (self.seed as i64).into()),
            ("noise_sigma", self.noise_sigma.into()),
            ("surrogate", self.surrogate.name().into()),
            ("objective", self.objective.name().into()),
            ("parallel", self.parallel.into()),
            (
                "max_seconds",
                match self.max_seconds {
                    Some(s) => s.into(),
                    None => Json::Null,
                },
            ),
            (
                "history_out",
                match &self.history_out {
                    Some(p) => p.display().to_string().into(),
                    None => Json::Null,
                },
            ),
            (
                "surrogate_addr",
                match &self.surrogate_addr {
                    Some(a) => a.as_str().into(),
                    None => Json::Null,
                },
            ),
            ("tune_lengthscale", self.tune_lengthscale.into()),
            (
                "objectives",
                match &self.objectives {
                    Some(set) => set.spec().into(),
                    None => Json::Null,
                },
            ),
            (
                "scalarize",
                match &self.scalarize {
                    Some(s) => s.spec().into(),
                    None => Json::Null,
                },
            ),
            (
                "state_dir",
                match &self.state_dir {
                    Some(p) => p.display().to_string().into(),
                    None => Json::Null,
                },
            ),
            ("resume", self.resume.into()),
            ("score_threads", self.score_threads.into()),
            ("score_tier", self.score_tier.name().into()),
            ("shard_cap", self.shard_cap.into()),
            ("blend_k", self.blend_k.into()),
            (
                "events_file",
                match &self.events_file {
                    Some(p) => p.display().to_string().into(),
                    None => Json::Null,
                },
            ),
        ])
    }

    pub fn from_json(j: &Json) -> Result<TuneConfig> {
        let mut cfg = TuneConfig::default();
        if let Some(m) = j.get("model").and_then(Json::as_str) {
            cfg.model = ModelId::parse(m).with_context(|| format!("unknown model '{m}'"))?;
        }
        if let Some(a) = j.get("algorithm").and_then(Json::as_str) {
            cfg.algorithm =
                Algorithm::parse(a).with_context(|| format!("unknown algorithm '{a}'"))?;
        }
        if let Some(n) = j.get("iterations").and_then(Json::as_i64) {
            anyhow::ensure!(n > 0, "iterations must be positive");
            cfg.iterations = n as usize;
        }
        if let Some(s) = j.get("seed").and_then(Json::as_i64) {
            cfg.seed = s as u64;
        }
        if let Some(s) = j.get("noise_sigma").and_then(Json::as_f64) {
            anyhow::ensure!(s >= 0.0, "noise_sigma must be non-negative");
            cfg.noise_sigma = s;
        }
        if let Some(s) = j.get("surrogate").and_then(Json::as_str) {
            cfg.surrogate =
                SurrogateKind::parse(s).with_context(|| format!("unknown surrogate '{s}'"))?;
        }
        if let Some(o) = j.get("objective").and_then(Json::as_str) {
            cfg.objective = crate::evaluator::Objective::parse(o)
                .with_context(|| format!("unknown objective '{o}'"))?;
        }
        if let Some(p) = j.get("parallel").and_then(Json::as_i64) {
            anyhow::ensure!(p > 0, "parallel must be positive");
            cfg.parallel = p as usize;
        }
        if let Some(s) = j.get("max_seconds").and_then(Json::as_f64) {
            anyhow::ensure!(s > 0.0, "max_seconds must be positive");
            cfg.max_seconds = Some(s);
        }
        if let Some(p) = j.get("history_out").and_then(Json::as_str) {
            cfg.history_out = Some(PathBuf::from(p));
        }
        if let Some(a) = j.get("surrogate_addr").and_then(Json::as_str) {
            cfg.surrogate_addr = Some(a.to_string());
        }
        if let Some(t) = j.get("tune_lengthscale").and_then(Json::as_bool) {
            cfg.tune_lengthscale = t;
        }
        if let Some(o) = j.get("objectives").and_then(Json::as_str) {
            cfg.objectives = Some(
                crate::objectives::ObjectiveSet::parse(o)
                    .map_err(|e| anyhow::anyhow!("bad objectives '{o}': {e}"))?,
            );
        }
        if let Some(s) = j.get("scalarize").and_then(Json::as_str) {
            cfg.scalarize = Some(
                crate::objectives::Scalarization::parse(s)
                    .map_err(|e| anyhow::anyhow!("bad scalarize '{s}': {e}"))?,
            );
        }
        if let Some(p) = j.get("state_dir").and_then(Json::as_str) {
            cfg.state_dir = Some(PathBuf::from(p));
        }
        if let Some(r) = j.get("resume").and_then(Json::as_bool) {
            cfg.resume = r;
        }
        if let Some(t) = j.get("score_threads").and_then(Json::as_i64) {
            anyhow::ensure!(t > 0, "score_threads must be positive");
            cfg.score_threads = t as usize;
        }
        if let Some(t) = j.get("score_tier").and_then(Json::as_str) {
            cfg.score_tier = crate::gp::ScoreTier::parse(t)
                .with_context(|| format!("unknown score tier '{t}' (f64|f32)"))?;
        }
        if let Some(n) = j.get("shard_cap").and_then(Json::as_i64) {
            anyhow::ensure!(n > 0, "shard_cap must be positive");
            cfg.shard_cap = n as usize;
        }
        if let Some(n) = j.get("blend_k").and_then(Json::as_i64) {
            anyhow::ensure!(n > 0, "blend_k must be positive");
            cfg.blend_k = n as usize;
        }
        if let Some(p) = j.get("events_file").and_then(Json::as_str) {
            cfg.events_file = Some(PathBuf::from(p));
        }
        Ok(cfg)
    }

    pub fn load(path: &Path) -> Result<TuneConfig> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading config {}", path.display()))?;
        let j = parse(&text).map_err(|e| anyhow::anyhow!("parsing config: {e}"))?;
        TuneConfig::from_json(&j)
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        std::fs::write(path, format!("{}\n", self.to_json()))?;
        Ok(())
    }
}

impl TuneConfig {
    /// Build the tuning engine this spec asks for, honouring the surrogate
    /// choice for BO (HLO = the AOT artifact via PJRT), the surrogate
    /// service attachment and the lengthscale-selection flag. `Send` so
    /// the session can be driven from a `SessionGroup` thread.
    pub fn build_tuner(&self) -> Result<Box<dyn crate::algorithms::Tuner + Send>> {
        self.build_tuner_events(None)
    }

    /// [`TuneConfig::build_tuner`] with the observability plane attached:
    /// when `events` is a live bus, the remote replica emits
    /// `sync-factor`/`lease-published` under the `"replica"` source and a
    /// local sharded factor emits `surrogate-tell`/`surrogate-drain`/
    /// `factor-size` under `"surrogate"`.
    pub fn build_tuner_events(
        &self,
        events: Option<&crate::obs::EventBus>,
    ) -> Result<Box<dyn crate::algorithms::Tuner + Send>> {
        /// Attach the BO-only run-spec options in the required order:
        /// remote factor replica first (the engine adopts the service's
        /// hypers), then lengthscale selection (in-guard changes write
        /// back through the replica's `set-hyper` hook, so siblings
        /// converge on one hyper), then the declared objective set.
        fn finish<S: crate::gp::Surrogate + Send + 'static>(
            mut bo: crate::algorithms::BayesOpt<S>,
            cfg: &TuneConfig,
            events: Option<&crate::obs::EventBus>,
        ) -> Result<Box<dyn crate::algorithms::Tuner + Send>> {
            if let Some(addr) = &cfg.surrogate_addr {
                // Fingerprinted attach: a v4 fleet daemon binds (or lazily
                // creates) the space matching this run's model, so tuners
                // of different models against one daemon never contend;
                // pre-v4 daemons fall back to their single default space.
                let replica =
                    crate::gp::RemoteSurrogate::connect_space(addr, &cfg.model.space())
                        .with_context(|| format!("attaching surrogate service {addr}"))?;
                if let Some(bus) = events {
                    replica.set_event_source(bus.source("replica"));
                }
                bo = bo.with_shared_surrogate(replica);
            }
            if cfg.tune_lengthscale {
                bo = bo.with_lengthscale_selection();
            }
            if let Some(set) = &cfg.objectives {
                bo = bo.with_objectives(set.clone(), cfg.resolved_scalarize()?);
            }
            bo = bo
                .with_score_threads(cfg.score_threads.max(1))
                .with_score_tier(cfg.score_tier);
            Ok(Box::new(bo))
        }

        let space = self.model.space();
        anyhow::ensure!(
            self.objectives.is_some() || self.scalarize.is_none(),
            "scalarize requires a declared objective set (--objectives)"
        );
        if self.algorithm == Algorithm::Bo {
            return match self.surrogate {
                SurrogateKind::Hlo => {
                    anyhow::ensure!(
                        self.objectives.is_none(),
                        "multi-objective tuning requires the native surrogate \
                         (the AOT HLO artifact's fused graph is single-objective)"
                    );
                    let surrogate = crate::runtime::GpSurrogate::open_default()
                        .context("loading the GP HLO artifact (run `make artifacts`)")?;
                    finish(
                        crate::algorithms::BayesOpt::with_surrogate(space, self.seed, surrogate),
                        self,
                        events,
                    )
                }
                SurrogateKind::Native => {
                    finish(crate::algorithms::BayesOpt::new(space, self.seed), self, events)
                }
                SurrogateKind::Sharded => {
                    // The sharded tier is a *local* scaling engine. A
                    // remote factor's tier is the daemon's decision
                    // (`surrogate-serve --surrogate sharded` /
                    // `--max-rows-per-space`), so combining both here
                    // would silently shadow the service's model.
                    anyhow::ensure!(
                        self.surrogate_addr.is_none(),
                        "surrogate 'sharded' is a local scaling tier and cannot attach to a \
                         surrogate service; pick the tier on the daemon instead \
                         (surrogate-serve --surrogate sharded / --max-rows-per-space)"
                    );
                    let shared = crate::gp::SharedSurrogate::new_sharded(
                        crate::gp::GpHyper::default(),
                        self.shard_cap,
                        self.blend_k,
                    );
                    if let Some(bus) = events {
                        shared.set_event_source(bus.source("surrogate"));
                    }
                    finish(
                        crate::algorithms::BayesOpt::new(space, self.seed)
                            .with_shared_surrogate(shared),
                        self,
                        events,
                    )
                }
            };
        }
        anyhow::ensure!(
            self.surrogate != SurrogateKind::Sharded,
            "surrogate 'sharded' applies to the BO engine only (got {})",
            self.algorithm.name()
        );
        anyhow::ensure!(
            self.surrogate_addr.is_none(),
            "surrogate_addr applies to the BO engine only (got {})",
            self.algorithm.name()
        );
        anyhow::ensure!(
            !self.tune_lengthscale,
            "tune_lengthscale applies to the BO engine only (got {})",
            self.algorithm.name()
        );
        anyhow::ensure!(
            self.objectives.is_none(),
            "objectives applies to the BO engine only (got {})",
            self.algorithm.name()
        );
        anyhow::ensure!(
            self.score_threads <= 1,
            "score_threads applies to the BO engine only (got {})",
            self.algorithm.name()
        );
        anyhow::ensure!(
            self.score_tier == crate::gp::ScoreTier::F64,
            "score_tier applies to the BO engine only (got {})",
            self.algorithm.name()
        );
        Ok(self.algorithm.build(&space, self.seed))
    }

    /// The scalarisation a multi-objective run will use: the declared one
    /// (weights validated against the objective count) or equal weights.
    pub fn resolved_scalarize(&self) -> Result<crate::objectives::Scalarization> {
        let set = self
            .objectives
            .as_ref()
            .ok_or_else(|| anyhow::anyhow!("no objective set declared"))?;
        self.scalarize
            .clone()
            .unwrap_or(crate::objectives::Scalarization::Weighted(Vec::new()))
            .resolve(set.k())
            .map_err(|e| anyhow::anyhow!("bad scalarisation: {e}"))
    }

    /// Build the `TuningSession` this spec describes: the engine, a pool
    /// of `parallel` simulator evaluators, and the budget (iterations plus
    /// the optional wall-clock cap). A declared objective set is wired
    /// into both the engine (acquisition) and the session (history
    /// recording).
    pub fn build_session(&self) -> Result<crate::session::TuningSession> {
        self.build_session_events(None)
    }

    /// [`TuneConfig::build_session`] with the observability plane
    /// attached: the session emits trial/ask/front events under the
    /// `"session"` source and the tuner's surrogate handles are wired per
    /// [`TuneConfig::build_tuner_events`].
    pub fn build_session_events(
        &self,
        events: Option<&crate::obs::EventBus>,
    ) -> Result<crate::session::TuningSession> {
        let tuner = self.build_tuner_events(events)?;
        let pool = crate::evaluator::sim_pool(
            self.model,
            self.seed,
            self.noise_sigma,
            self.objective,
            self.parallel.max(1),
        );
        let mut budget = crate::session::Budget::evaluations(self.iterations);
        if let Some(s) = self.max_seconds {
            budget = budget.with_max_seconds(s);
        }
        let mut session = crate::session::TuningSession::new(tuner, pool, budget);
        if let Some(set) = &self.objectives {
            session = session.with_objectives(set.clone());
        }
        if let Some(bus) = events {
            session = session.with_events(bus.source("session"));
        }
        Ok(session)
    }

    /// Execute the run against the simulated target and return the history
    /// (persisted to `history_out` when set). `parallel == 1` reproduces
    /// the serial propose→apply→measure loop exactly.
    ///
    /// With `state_dir` set, every completed trial is additionally
    /// streamed to `state_dir/session.jsonl` as it lands, and `resume`
    /// continues an interrupted run: prior trials are warm-started into a
    /// fresh engine and only the *remaining* budget is spent (the
    /// returned history is prior + new, in completion order).
    pub fn run(&self) -> Result<crate::history::History> {
        // The observability plane: one bus for the whole run, draining to
        // the JSONL file sink. Built before the session so the tuner's
        // surrogate handles and the session driver share it; flushed (a
        // collector barrier) before the run returns so the file holds
        // every emitted record.
        let events = match &self.events_file {
            Some(path) => {
                let bus = crate::obs::EventBus::new();
                bus.attach(Box::new(crate::obs::FileSink::create(path)?));
                Some(bus)
            }
            None => None,
        };
        let Some(dir) = self.state_dir.clone() else {
            anyhow::ensure!(!self.resume, "resume requires a state directory (--state-dir)");
            let mut session = self.build_session_events(events.as_ref())?;
            let history = session.run()?;
            if let Some(bus) = &events {
                bus.flush();
            }
            if let Some(path) = &self.history_out {
                history.save(path, &self.model.space())?;
            }
            return Ok(history);
        };

        let space = self.model.space();
        let log_path = dir.join(SESSION_LOG);
        let prior = if self.resume && log_path.exists() {
            crate::history::History::load(&log_path, &space)
                .with_context(|| format!("loading session journal {}", log_path.display()))?
        } else {
            crate::history::History::new()
        };

        let done = prior.len();
        if done >= self.iterations {
            // The interrupted run had already finished its budget.
            if let Some(path) = &self.history_out {
                prior.save(path, &space)?;
            }
            return Ok(prior);
        }

        // A fresh engine warm-started from the journal: the BO store gets
        // every prior row (all objective columns), so its posterior
        // conditions on the full interrupted campaign before the first
        // new proposal.
        let mut tuner = self.build_tuner_events(events.as_ref())?;
        for e in prior.iter() {
            tuner.warm_start_obs(&e.config, e.value, &e.objectives);
        }

        std::fs::create_dir_all(&dir)
            .with_context(|| format!("creating state dir {}", dir.display()))?;
        let log = if self.resume {
            std::fs::OpenOptions::new().create(true).append(true).open(&log_path)
        } else {
            // A cold durable run owns the journal: truncate any stale one.
            std::fs::File::create(&log_path)
        }
        .with_context(|| format!("opening session journal {}", log_path.display()))?;

        let pool = crate::evaluator::sim_pool(
            self.model,
            self.seed,
            self.noise_sigma,
            self.objective,
            self.parallel.max(1),
        );
        let mut budget = crate::session::Budget::evaluations(self.iterations - done);
        if let Some(s) = self.max_seconds {
            budget = budget.with_max_seconds(s);
        }

        // Stream each completed trial to the journal the moment it lands,
        // fsync'd per record: a measurement is real evaluation time, so
        // losing one to a crash costs more than the fsync.
        let journal_space = space.clone();
        let journal_set = self.objectives.clone();
        let mut log = log;
        let mut iteration = done;
        let mut session = crate::session::TuningSession::new(tuner, pool, budget).on_trial(
            move |trial, m| {
                use std::io::Write as _;
                let objectives = match &journal_set {
                    Some(set) => set.extract(m).0,
                    None => Vec::new(),
                };
                let e = crate::history::Evaluation {
                    config: trial.config.clone(),
                    value: m.value,
                    iteration,
                    trial_id: trial.id,
                    cost_s: m.cost_s,
                    objectives,
                };
                iteration += 1;
                if writeln!(log, "{}", e.to_json_line(&journal_space))
                    .and_then(|()| log.sync_data())
                    .is_err()
                {
                    eprintln!(
                        "tftune: session journal write failed; resume may lose this trial"
                    );
                }
            },
        );
        if let Some(set) = &self.objectives {
            session = session.with_objectives(set.clone());
        }
        if let Some(bus) = &events {
            session = session.with_events(bus.source("session"));
        }
        let fresh = session.run()?;
        if let Some(bus) = &events {
            bus.flush();
        }

        // prior + new, renumbered in completion order (matches the
        // journal on disk).
        let mut merged = prior;
        for e in fresh.iter() {
            let m = crate::history::Measurement::new(e.value).with_cost_s(e.cost_s);
            merged.push_trial_multi(e.trial_id, e.config.clone(), &m, e.objectives.clone());
        }
        if let Some(path) = &self.history_out {
            merged.save(path, &space)?;
        }
        Ok(merged)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_is_paper_budget() {
        let c = TuneConfig::default();
        assert_eq!(c.iterations, 50);
        assert_eq!(c.algorithm, Algorithm::Bo);
    }

    #[test]
    fn json_round_trip() {
        let mut c = TuneConfig::default();
        c.model = ModelId::BertFp32;
        c.algorithm = Algorithm::Nms;
        c.iterations = 25;
        c.seed = 99;
        c.surrogate = SurrogateKind::Hlo;
        c.parallel = 4;
        c.max_seconds = Some(12.5);
        c.history_out = Some(PathBuf::from("/tmp/h.jsonl"));
        c.surrogate_addr = Some("127.0.0.1:7071".to_string());
        c.tune_lengthscale = true;
        c.objectives =
            Some(crate::objectives::ObjectiveSet::parse("throughput,p99:min").unwrap());
        c.scalarize =
            Some(crate::objectives::Scalarization::parse("weighted:0.7,0.3").unwrap());
        c.state_dir = Some(PathBuf::from("/tmp/state"));
        c.resume = true;
        c.score_threads = 4;
        c.score_tier = crate::gp::ScoreTier::F32;
        c.shard_cap = 128;
        c.blend_k = 3;
        c.events_file = Some(PathBuf::from("/tmp/events.jsonl"));
        let j = c.to_json();
        let c2 = TuneConfig::from_json(&j).unwrap();
        assert_eq!(c2.model, ModelId::BertFp32);
        assert_eq!(c2.algorithm, Algorithm::Nms);
        assert_eq!(c2.iterations, 25);
        assert_eq!(c2.seed, 99);
        assert_eq!(c2.surrogate, SurrogateKind::Hlo);
        assert_eq!(c2.parallel, 4);
        assert_eq!(c2.max_seconds, Some(12.5));
        assert_eq!(c2.history_out, Some(PathBuf::from("/tmp/h.jsonl")));
        assert_eq!(c2.surrogate_addr, Some("127.0.0.1:7071".to_string()));
        assert!(c2.tune_lengthscale);
        assert_eq!(c2.objectives, c.objectives);
        assert_eq!(c2.scalarize, c.scalarize);
        assert_eq!(c2.state_dir, Some(PathBuf::from("/tmp/state")));
        assert!(c2.resume);
        assert_eq!(c2.score_threads, 4);
        assert_eq!(c2.score_tier, crate::gp::ScoreTier::F32);
        assert_eq!(c2.shard_cap, 128);
        assert_eq!(c2.blend_k, 3);
        assert_eq!(c2.events_file, Some(PathBuf::from("/tmp/events.jsonl")));
    }

    #[test]
    fn resume_without_state_dir_is_rejected() {
        let c = TuneConfig { resume: true, iterations: 2, ..TuneConfig::default() };
        let err = c.run().unwrap_err();
        assert!(err.to_string().contains("state directory"), "{err}");
    }

    #[test]
    fn durable_run_streams_and_resumes_the_budget() {
        let dir = std::env::temp_dir().join("tftune_cfg_resume");
        std::fs::remove_dir_all(&dir).ok();
        let base = TuneConfig {
            model: ModelId::NcfFp32,
            algorithm: Algorithm::Random,
            iterations: 6,
            seed: 17,
            noise_sigma: 0.0,
            state_dir: Some(dir.clone()),
            ..TuneConfig::default()
        };
        // An "interrupted" run: 6 of 10 iterations, journaled as it goes.
        let first = base.run().unwrap();
        assert_eq!(first.len(), 6);
        let space = base.model.space();
        let journal =
            crate::history::History::load(&dir.join(SESSION_LOG), &space).unwrap();
        assert_eq!(journal.len(), 6, "every completed trial streams to the journal");
        assert_eq!(journal.values(), first.values());

        // Resume with a larger budget: only the remainder is spent, and
        // the merged history starts with the prior trials verbatim.
        let resumed_cfg =
            TuneConfig { iterations: 10, resume: true, ..base.clone() };
        let resumed = resumed_cfg.run().unwrap();
        assert_eq!(resumed.len(), 10);
        assert_eq!(&resumed.values()[..6], &first.values()[..]);
        let journal =
            crate::history::History::load(&dir.join(SESSION_LOG), &space).unwrap();
        assert_eq!(journal.len(), 10, "resumed trials append to the same journal");

        // Resuming a finished budget is a no-op returning the journal.
        let done = TuneConfig { iterations: 10, resume: true, ..base.clone() };
        let again = done.run().unwrap();
        assert_eq!(again.len(), 10);
        assert_eq!(again.values(), resumed.values());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn bo_only_options_rejected_for_other_engines() {
        let mut c = TuneConfig { algorithm: Algorithm::Random, ..TuneConfig::default() };
        c.surrogate_addr = Some("127.0.0.1:7071".to_string());
        let err = c.build_tuner().unwrap_err();
        assert!(err.to_string().contains("BO engine only"), "{err}");
        c.surrogate_addr = None;
        c.tune_lengthscale = true;
        let err = c.build_tuner().unwrap_err();
        assert!(err.to_string().contains("BO engine only"), "{err}");
        c.tune_lengthscale = false;
        c.objectives =
            Some(crate::objectives::ObjectiveSet::parse("throughput,p99:min").unwrap());
        let err = c.build_tuner().unwrap_err();
        assert!(err.to_string().contains("BO engine only"), "{err}");
        c.objectives = None;
        c.score_threads = 4;
        let err = c.build_tuner().unwrap_err();
        assert!(err.to_string().contains("BO engine only"), "{err}");
        c.score_threads = 1;
        c.score_tier = crate::gp::ScoreTier::F32;
        let err = c.build_tuner().unwrap_err();
        assert!(err.to_string().contains("BO engine only"), "{err}");
    }

    #[test]
    fn lengthscale_selection_with_remote_factor_builds() {
        // Since the replica's set-hyper write-through landed, in-guard
        // lengthscale selection publishes to the service instead of
        // fighting it — the combination is legal now.
        let (server, _factor) = crate::server::TargetServer::bind_surrogate_only(
            "127.0.0.1:0",
            crate::gp::GpHyper::default(),
        )
        .unwrap();
        let (addr, handle) = server.spawn().unwrap();
        let mut c = TuneConfig::default();
        c.surrogate_addr = Some(addr.to_string());
        c.tune_lengthscale = true;
        let mut tuner = c.build_tuner().unwrap();
        use crate::algorithms::Tuner as _;
        assert_eq!(tuner.ask(1).len(), 1);
        drop(tuner);
        // shut the daemon down via the evaluate plane
        {
            use std::io::Write;
            let space = crate::space::threading_space(64, 1024, 64);
            let mut s = std::net::TcpStream::connect(addr).unwrap();
            let _ = writeln!(
                s,
                "{}",
                crate::server::proto::encode_request(
                    &crate::server::proto::Request::Shutdown,
                    &space
                )
            );
        }
        let _ = handle.join();
    }

    #[test]
    fn multi_objective_spec_builds_and_rejects_misuse() {
        use crate::algorithms::Tuner as _;
        let mut c = TuneConfig::default();
        c.objectives =
            Some(crate::objectives::ObjectiveSet::parse("throughput,p99_latency_ms:min").unwrap());
        c.scalarize = Some(crate::objectives::Scalarization::Smsego);
        let mut tuner = c.build_tuner().unwrap();
        assert_eq!(tuner.name(), "bayesian-optimization");
        assert_eq!(tuner.ask(1).len(), 1);

        // scalarize without objectives is meaningless
        let mut bad = TuneConfig::default();
        bad.scalarize = Some(crate::objectives::Scalarization::Smsego);
        let err = bad.build_tuner().unwrap_err();
        assert!(err.to_string().contains("requires a declared objective set"), "{err}");

        // weight-count mismatch is a config error, not a panic
        let mut mismatch = TuneConfig::default();
        mismatch.objectives =
            Some(crate::objectives::ObjectiveSet::parse("throughput,p99:min").unwrap());
        mismatch.scalarize =
            Some(crate::objectives::Scalarization::parse("weighted:1,2,3").unwrap());
        let err = mismatch.build_tuner().unwrap_err();
        assert!(err.to_string().contains("bad scalarisation"), "{err}");

        // the HLO artifact path is single-objective
        let mut hlo = TuneConfig::default();
        hlo.objectives =
            Some(crate::objectives::ObjectiveSet::parse("throughput,p99:min").unwrap());
        hlo.surrogate = SurrogateKind::Hlo;
        let err = hlo.build_tuner().unwrap_err();
        assert!(err.to_string().contains("native surrogate"), "{err}");
    }

    #[test]
    fn tune_lengthscale_spec_builds_a_selecting_engine() {
        use crate::algorithms::Tuner as _;
        let c = TuneConfig { tune_lengthscale: true, ..TuneConfig::default() };
        // Native BO with selection builds fine (the selection itself is
        // pinned in rust/tests/artifact_gp.rs).
        let mut tuner = c.build_tuner().unwrap();
        assert_eq!(tuner.name(), "bayesian-optimization");
        assert_eq!(tuner.ask(1).len(), 1);
    }

    #[test]
    fn rejects_bad_values() {
        let j = parse(r#"{"model":"made-up"}"#).unwrap();
        assert!(TuneConfig::from_json(&j).is_err());
        let j = parse(r#"{"iterations":0}"#).unwrap();
        assert!(TuneConfig::from_json(&j).is_err());
        let j = parse(r#"{"noise_sigma":-1}"#).unwrap();
        assert!(TuneConfig::from_json(&j).is_err());
        let j = parse(r#"{"parallel":0}"#).unwrap();
        assert!(TuneConfig::from_json(&j).is_err());
        let j = parse(r#"{"max_seconds":-2}"#).unwrap();
        assert!(TuneConfig::from_json(&j).is_err());
        let j = parse(r#"{"score_threads":0}"#).unwrap();
        assert!(TuneConfig::from_json(&j).is_err());
        let j = parse(r#"{"score_tier":"f16"}"#).unwrap();
        assert!(TuneConfig::from_json(&j).is_err());
        let j = parse(r#"{"shard_cap":0}"#).unwrap();
        assert!(TuneConfig::from_json(&j).is_err());
        let j = parse(r#"{"blend_k":0}"#).unwrap();
        assert!(TuneConfig::from_json(&j).is_err());
        let j = parse(r#"{"surrogate":"made-up"}"#).unwrap();
        assert!(TuneConfig::from_json(&j).is_err());
    }

    #[test]
    fn sharded_spec_builds_and_rejects_misuse() {
        use crate::algorithms::Tuner as _;
        // "exact" is accepted as an alias of the flat native engine.
        assert_eq!(SurrogateKind::parse("exact"), Some(SurrogateKind::Native));
        assert_eq!(SurrogateKind::parse("sharded"), Some(SurrogateKind::Sharded));

        let c = TuneConfig {
            surrogate: SurrogateKind::Sharded,
            shard_cap: 64,
            blend_k: 2,
            ..TuneConfig::default()
        };
        let mut tuner = c.build_tuner().unwrap();
        assert_eq!(tuner.name(), "bayesian-optimization");
        assert_eq!(tuner.ask(1).len(), 1);

        // Local sharded tier + remote factor attachment is contradictory.
        let mut remote = TuneConfig { surrogate: SurrogateKind::Sharded, ..TuneConfig::default() };
        remote.surrogate_addr = Some("127.0.0.1:7071".to_string());
        let err = remote.build_tuner().unwrap_err();
        assert!(err.to_string().contains("local scaling tier"), "{err}");

        // Sharded is a BO-engine surrogate.
        let ga = TuneConfig {
            surrogate: SurrogateKind::Sharded,
            algorithm: Algorithm::Ga,
            ..TuneConfig::default()
        };
        let err = ga.build_tuner().unwrap_err();
        assert!(err.to_string().contains("BO engine only"), "{err}");
    }

    #[test]
    fn scoring_engine_knobs_build_a_bo_engine() {
        use crate::algorithms::Tuner as _;
        let c = TuneConfig {
            score_threads: 4,
            score_tier: crate::gp::ScoreTier::F32,
            ..TuneConfig::default()
        };
        let mut tuner = c.build_tuner().unwrap();
        assert_eq!(tuner.name(), "bayesian-optimization");
        assert_eq!(tuner.ask(1).len(), 1);
    }

    #[test]
    fn file_round_trip() {
        let dir = std::env::temp_dir().join("tftune_cfg_test");
        let path = dir.join("run.json");
        let c = TuneConfig::default();
        c.save(&path).unwrap();
        let c2 = TuneConfig::load(&path).unwrap();
        assert_eq!(c2.iterations, c.iterations);
        std::fs::remove_dir_all(&dir).ok();
    }
}
