//! Run-spec configuration: a JSON description of a tuning run (model,
//! algorithm, budget, seeds, surrogate backend, output locations), loadable
//! from a file or assembled from CLI flags. Every launcher entry point
//! (CLI, benches, examples) goes through this, so runs are reproducible
//! from a single artifact.

use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::algorithms::Algorithm;
use crate::sim::ModelId;
use crate::util::json::{parse, Json};

/// Which GP surrogate backs the BO engine.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SurrogateKind {
    /// Exact native-Rust GP (no artifacts needed).
    Native,
    /// The AOT HLO artifact via PJRT (production path).
    Hlo,
}

impl SurrogateKind {
    pub fn parse(s: &str) -> Option<SurrogateKind> {
        match s.to_lowercase().as_str() {
            "native" => Some(SurrogateKind::Native),
            "hlo" | "pjrt" | "artifact" => Some(SurrogateKind::Hlo),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            SurrogateKind::Native => "native",
            SurrogateKind::Hlo => "hlo",
        }
    }
}

/// A complete tuning-run specification.
#[derive(Debug, Clone)]
pub struct TuneConfig {
    pub model: ModelId,
    pub algorithm: Algorithm,
    /// Evaluation budget (the paper caps at 50).
    pub iterations: usize,
    pub seed: u64,
    /// Measurement-noise sigma for the simulated target.
    pub noise_sigma: f64,
    pub surrogate: SurrogateKind,
    /// What the tuner maximises (throughput or inverse latency).
    pub objective: crate::evaluator::Objective,
    /// Simulator evaluators measuring in parallel (1 = exact serial loop).
    pub parallel: usize,
    /// Optional wall-clock limit wired into the session `Budget`.
    pub max_seconds: Option<f64>,
    /// Where to write the history JSONL (None = don't persist).
    pub history_out: Option<PathBuf>,
}

impl Default for TuneConfig {
    fn default() -> Self {
        TuneConfig {
            model: ModelId::Resnet50Int8,
            algorithm: Algorithm::Bo,
            iterations: 50,
            seed: 0,
            noise_sigma: crate::sim::noise::DEFAULT_SIGMA,
            surrogate: SurrogateKind::Native,
            objective: crate::evaluator::Objective::Throughput,
            parallel: 1,
            max_seconds: None,
            history_out: None,
        }
    }
}

impl TuneConfig {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("model", self.model.short_name().into()),
            ("algorithm", self.algorithm.name().into()),
            ("iterations", self.iterations.into()),
            ("seed", (self.seed as i64).into()),
            ("noise_sigma", self.noise_sigma.into()),
            ("surrogate", self.surrogate.name().into()),
            ("objective", self.objective.name().into()),
            ("parallel", self.parallel.into()),
            (
                "max_seconds",
                match self.max_seconds {
                    Some(s) => s.into(),
                    None => Json::Null,
                },
            ),
            (
                "history_out",
                match &self.history_out {
                    Some(p) => p.display().to_string().into(),
                    None => Json::Null,
                },
            ),
        ])
    }

    pub fn from_json(j: &Json) -> Result<TuneConfig> {
        let mut cfg = TuneConfig::default();
        if let Some(m) = j.get("model").and_then(Json::as_str) {
            cfg.model = ModelId::parse(m).with_context(|| format!("unknown model '{m}'"))?;
        }
        if let Some(a) = j.get("algorithm").and_then(Json::as_str) {
            cfg.algorithm =
                Algorithm::parse(a).with_context(|| format!("unknown algorithm '{a}'"))?;
        }
        if let Some(n) = j.get("iterations").and_then(Json::as_i64) {
            anyhow::ensure!(n > 0, "iterations must be positive");
            cfg.iterations = n as usize;
        }
        if let Some(s) = j.get("seed").and_then(Json::as_i64) {
            cfg.seed = s as u64;
        }
        if let Some(s) = j.get("noise_sigma").and_then(Json::as_f64) {
            anyhow::ensure!(s >= 0.0, "noise_sigma must be non-negative");
            cfg.noise_sigma = s;
        }
        if let Some(s) = j.get("surrogate").and_then(Json::as_str) {
            cfg.surrogate =
                SurrogateKind::parse(s).with_context(|| format!("unknown surrogate '{s}'"))?;
        }
        if let Some(o) = j.get("objective").and_then(Json::as_str) {
            cfg.objective = crate::evaluator::Objective::parse(o)
                .with_context(|| format!("unknown objective '{o}'"))?;
        }
        if let Some(p) = j.get("parallel").and_then(Json::as_i64) {
            anyhow::ensure!(p > 0, "parallel must be positive");
            cfg.parallel = p as usize;
        }
        if let Some(s) = j.get("max_seconds").and_then(Json::as_f64) {
            anyhow::ensure!(s > 0.0, "max_seconds must be positive");
            cfg.max_seconds = Some(s);
        }
        if let Some(p) = j.get("history_out").and_then(Json::as_str) {
            cfg.history_out = Some(PathBuf::from(p));
        }
        Ok(cfg)
    }

    pub fn load(path: &Path) -> Result<TuneConfig> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading config {}", path.display()))?;
        let j = parse(&text).map_err(|e| anyhow::anyhow!("parsing config: {e}"))?;
        TuneConfig::from_json(&j)
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        std::fs::write(path, format!("{}\n", self.to_json()))?;
        Ok(())
    }
}

impl TuneConfig {
    /// Build the tuning engine this spec asks for, honouring the surrogate
    /// choice for BO (HLO = the AOT artifact via PJRT). `Send` so the
    /// session can be driven from a `SessionGroup` thread.
    pub fn build_tuner(&self) -> Result<Box<dyn crate::algorithms::Tuner + Send>> {
        let space = self.model.space();
        if self.algorithm == Algorithm::Bo && self.surrogate == SurrogateKind::Hlo {
            let surrogate = crate::runtime::GpSurrogate::open_default()
                .context("loading the GP HLO artifact (run `make artifacts`)")?;
            return Ok(Box::new(crate::algorithms::BayesOpt::with_surrogate(
                space, self.seed, surrogate,
            )));
        }
        Ok(self.algorithm.build(&space, self.seed))
    }

    /// Build the `TuningSession` this spec describes: the engine, a pool
    /// of `parallel` simulator evaluators, and the budget (iterations plus
    /// the optional wall-clock cap).
    pub fn build_session(&self) -> Result<crate::session::TuningSession> {
        let tuner = self.build_tuner()?;
        let pool = crate::evaluator::sim_pool(
            self.model,
            self.seed,
            self.noise_sigma,
            self.objective,
            self.parallel.max(1),
        );
        let mut budget = crate::session::Budget::evaluations(self.iterations);
        if let Some(s) = self.max_seconds {
            budget = budget.with_max_seconds(s);
        }
        Ok(crate::session::TuningSession::new(tuner, pool, budget))
    }

    /// Execute the run against the simulated target and return the history
    /// (persisted to `history_out` when set). `parallel == 1` reproduces
    /// the serial propose→apply→measure loop exactly.
    pub fn run(&self) -> Result<crate::history::History> {
        let mut session = self.build_session()?;
        let history = session.run()?;
        if let Some(path) = &self.history_out {
            history.save(path, &self.model.space())?;
        }
        Ok(history)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_is_paper_budget() {
        let c = TuneConfig::default();
        assert_eq!(c.iterations, 50);
        assert_eq!(c.algorithm, Algorithm::Bo);
    }

    #[test]
    fn json_round_trip() {
        let mut c = TuneConfig::default();
        c.model = ModelId::BertFp32;
        c.algorithm = Algorithm::Nms;
        c.iterations = 25;
        c.seed = 99;
        c.surrogate = SurrogateKind::Hlo;
        c.parallel = 4;
        c.max_seconds = Some(12.5);
        c.history_out = Some(PathBuf::from("/tmp/h.jsonl"));
        let j = c.to_json();
        let c2 = TuneConfig::from_json(&j).unwrap();
        assert_eq!(c2.model, ModelId::BertFp32);
        assert_eq!(c2.algorithm, Algorithm::Nms);
        assert_eq!(c2.iterations, 25);
        assert_eq!(c2.seed, 99);
        assert_eq!(c2.surrogate, SurrogateKind::Hlo);
        assert_eq!(c2.parallel, 4);
        assert_eq!(c2.max_seconds, Some(12.5));
        assert_eq!(c2.history_out, Some(PathBuf::from("/tmp/h.jsonl")));
    }

    #[test]
    fn rejects_bad_values() {
        let j = parse(r#"{"model":"made-up"}"#).unwrap();
        assert!(TuneConfig::from_json(&j).is_err());
        let j = parse(r#"{"iterations":0}"#).unwrap();
        assert!(TuneConfig::from_json(&j).is_err());
        let j = parse(r#"{"noise_sigma":-1}"#).unwrap();
        assert!(TuneConfig::from_json(&j).is_err());
        let j = parse(r#"{"parallel":0}"#).unwrap();
        assert!(TuneConfig::from_json(&j).is_err());
        let j = parse(r#"{"max_seconds":-2}"#).unwrap();
        assert!(TuneConfig::from_json(&j).is_err());
    }

    #[test]
    fn file_round_trip() {
        let dir = std::env::temp_dir().join("tftune_cfg_test");
        let path = dir.join("run.json");
        let c = TuneConfig::default();
        c.save(&path).unwrap();
        let c2 = TuneConfig::load(&path).unwrap();
        assert_eq!(c2.iterations, c.iterations);
        std::fs::remove_dir_all(&dir).ok();
    }
}
