//! Host-side client for the target daemon: an [`Evaluator`] that sends
//! configurations over TCP and reads back measurements — the optimization
//! framework's half of the paper's Fig. 4 deployment.
//!
//! Two usage modes:
//! - **blocking** (`Evaluator` impl): one request/response per call, used
//!   by a `TuningSession` pool with one connection per daemon address
//!   ([`RemoteEvaluator::connect_all`]). This path **reconnects with
//!   exponential backoff** on transient transport failure (daemon
//!   restart, dropped connection): the in-flight request is re-sent on
//!   the fresh connection — measurements are idempotent, so a re-measure
//!   is safe — and only after the retry budget
//!   ([`RemoteEvaluator::with_reconnect`]) is exhausted does the session
//!   see an error. Protocol-level errors (the target *answered* with
//!   `error`) are never retried: the daemon is healthy, the request is
//!   bad.
//! - **pipelined** ([`RemoteEvaluator::submit`] + [`RemoteEvaluator::recv_measurement`]):
//!   several trial-tagged requests in flight on one connection; the daemon
//!   answers in completion order and the trial id pairs each response with
//!   its trial. This path does *not* reconnect — a lost connection loses
//!   the in-flight trials, and silently re-submitting them is the
//!   caller's policy decision, not this client's.
//!
//! Either way, a daemon's measurement reaches the engine through
//! `Tuner::tell` — with a BO engine that means it *enqueues into the
//! shared surrogate* (`gp::SharedSurrogate`) and is folded into the
//! persistent factor, in arrival order, by the next ask. Tells never
//! block on a concurrent scoring pass, so slow daemons and surrogate
//! scoring overlap freely; `rust/tests/shared_surrogate.rs` pins that
//! shuffled, sharded completion orders condition the factor identically
//! to a serial run fed the same order.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::time::Duration;

use anyhow::{bail, Context, Result};

use super::Evaluator;
use crate::algorithms::{Trial, TrialId};
use crate::history::Measurement;
use crate::server::proto::{decode_response, encode_request, Request, Response};
use crate::space::{Config, SearchSpace};

/// Default reconnect attempts after a transport failure (initial connect
/// is not counted — `connect` fails fast so a bad address is loud).
const DEFAULT_RECONNECT_ATTEMPTS: usize = 4;
/// First backoff delay; doubles per attempt (20, 40, 80, 160 ms…).
const DEFAULT_RECONNECT_BASE: Duration = Duration::from_millis(20);

/// One live connection to the daemon.
struct Wire {
    writer: TcpStream,
    reader: BufReader<TcpStream>,
}

impl Wire {
    fn send(&mut self, req: &Request, space: &SearchSpace) -> Result<()> {
        writeln!(self.writer, "{}", encode_request(req, space))?;
        Ok(())
    }

    fn recv(&mut self, space: &SearchSpace) -> Result<Response> {
        let mut line = String::new();
        let n = self.reader.read_line(&mut line)?;
        if n == 0 {
            bail!("target closed the connection");
        }
        decode_response(line.trim_end(), space).map_err(|e| anyhow::anyhow!(e))
    }
}

pub struct RemoteEvaluator {
    addr: String,
    space: SearchSpace,
    /// `None` between a transport failure and the next successful redial.
    wire: Option<Wire>,
    description: String,
    reconnect_attempts: usize,
    reconnect_base: Duration,
}

impl RemoteEvaluator {
    /// Dial the daemon and run the describe handshake.
    fn dial(addr: &str, space: &SearchSpace) -> Result<(Wire, String)> {
        let stream = TcpStream::connect(addr).with_context(|| format!("connecting {addr}"))?;
        // One-line requests/responses: Nagle + delayed-ACK would add ~40 ms
        // per direction (measured 88 ms/eval before this; see EXPERIMENTS.md
        // §Perf).
        stream.set_nodelay(true)?;
        let writer = stream.try_clone()?;
        let mut wire = Wire { writer, reader: BufReader::new(stream) };
        wire.send(&Request::Describe, space)?;
        match wire.recv(space)? {
            Response::Target { description } => Ok((wire, description)),
            other => bail!("unexpected describe response: {other:?}"),
        }
    }

    /// Connect to a target daemon and fetch its description. Fails fast —
    /// the reconnect policy applies to *re*-connections only, so a wrong
    /// address errors immediately.
    pub fn connect(addr: &str, space: SearchSpace) -> Result<RemoteEvaluator> {
        let (wire, description) = Self::dial(addr, &space)?;
        Ok(RemoteEvaluator {
            addr: addr.to_string(),
            space,
            wire: Some(wire),
            description,
            reconnect_attempts: DEFAULT_RECONNECT_ATTEMPTS,
            reconnect_base: DEFAULT_RECONNECT_BASE,
        })
    }

    /// Override the reconnect policy of the blocking path: up to
    /// `attempts` redials after a transport failure, sleeping `base`,
    /// `2·base`, `4·base`, … between them. `attempts = 0` restores the
    /// old fail-on-first-error behaviour.
    pub fn with_reconnect(mut self, attempts: usize, base: Duration) -> RemoteEvaluator {
        self.reconnect_attempts = attempts;
        self.reconnect_base = base;
        self
    }

    /// One connection per comma-separated daemon address — the evaluator
    /// pool for a sharded `TuningSession` (`remote-tune --addr a:1,b:2`).
    pub fn connect_all(addrs: &str, space: &SearchSpace) -> Result<Vec<RemoteEvaluator>> {
        let mut out = Vec::new();
        for addr in addrs.split(',').map(str::trim).filter(|a| !a.is_empty()) {
            out.push(RemoteEvaluator::connect(addr, space.clone())?);
        }
        anyhow::ensure!(!out.is_empty(), "no daemon addresses in '{addrs}'");
        Ok(out)
    }

    /// The live wire plus the space it encodes with, for the pipelined
    /// (no-reconnect) path — split borrows so callers need no clone.
    fn wire(&mut self) -> Result<(&mut Wire, &SearchSpace)> {
        let RemoteEvaluator { wire, space, addr, .. } = self;
        let wire = wire.as_mut().with_context(|| {
            format!("connection to {addr} lost (pipelined path does not reconnect)")
        })?;
        Ok((wire, space))
    }

    /// Blocking request/response with reconnect-with-backoff on transport
    /// failure (module docs). The request is re-sent verbatim on every
    /// fresh connection.
    fn roundtrip(&mut self, req: &Request) -> Result<Response> {
        let mut delay = self.reconnect_base;
        let mut last_err: Option<anyhow::Error> = None;
        for attempt in 0..=self.reconnect_attempts {
            if attempt > 0 {
                std::thread::sleep(delay);
                delay = delay.saturating_mul(2);
            }
            if self.wire.is_none() {
                match Self::dial(&self.addr, &self.space) {
                    Ok((wire, description)) => {
                        eprintln!(
                            "tftune: reconnected to target {} (attempt {attempt})",
                            self.addr
                        );
                        self.wire = Some(wire);
                        self.description = description;
                    }
                    Err(e) => {
                        last_err = Some(e);
                        continue;
                    }
                }
            }
            let wire = self.wire.as_mut().expect("wire present after redial");
            let result = wire.send(req, &self.space).and_then(|()| wire.recv(&self.space));
            match result {
                Ok(resp) => return Ok(resp),
                Err(e) => {
                    // Transport failure: drop the wire; the next attempt
                    // redials. (Protocol errors arrive as Ok(Error{..})
                    // and are never retried.)
                    self.wire = None;
                    last_err = Some(e);
                }
            }
        }
        Err(last_err.expect("at least one attempt ran")).with_context(|| {
            format!(
                "target {} unreachable after {} reconnect attempt(s)",
                self.addr, self.reconnect_attempts
            )
        })
    }

    /// Pipeline a trial: send its tagged evaluate request without waiting
    /// for the response. No reconnect (module docs).
    pub fn submit(&mut self, trial: &Trial) -> Result<()> {
        let (wire, space) = self.wire()?;
        wire.send(
            &Request::Evaluate { config: trial.config.clone(), trial: Some(trial.id) },
            space,
        )
    }

    /// Block for the next completed measurement on this connection.
    /// Returns the trial id the daemon echoed (None for untagged requests)
    /// with the measurement, whose cost is the *target-side* wall clock.
    pub fn recv_measurement(&mut self) -> Result<(Option<TrialId>, Measurement)> {
        let (wire, space) = self.wire()?;
        match wire.recv(space)? {
            Response::Result { value, cost_s, trial, .. } => {
                Ok((trial, Measurement::new(value).with_cost_s(cost_s)))
            }
            Response::Error { message, .. } => bail!("target error: {message}"),
            other => bail!("unexpected response: {other:?}"),
        }
    }

    /// Ask the target daemon to shut down.
    pub fn shutdown(mut self) -> Result<()> {
        let (wire, space) = self.wire()?;
        wire.send(&Request::Shutdown, space)?;
        match wire.recv(space) {
            Ok(Response::Bye) | Err(_) => Ok(()),
            Ok(other) => bail!("unexpected shutdown response: {other:?}"),
        }
    }
}

impl Evaluator for RemoteEvaluator {
    fn evaluate(&mut self, config: &Config) -> Result<f64> {
        match self.roundtrip(&Request::Evaluate { config: config.clone(), trial: None })? {
            Response::Result { value, .. } => Ok(value),
            Response::Error { message, .. } => bail!("target error: {message}"),
            other => bail!("unexpected response: {other:?}"),
        }
    }

    fn measure(&mut self, config: &Config) -> Result<Measurement> {
        match self.roundtrip(&Request::Evaluate { config: config.clone(), trial: None })? {
            Response::Result { value, cost_s, .. } => {
                Ok(Measurement::new(value).with_cost_s(cost_s))
            }
            Response::Error { message, .. } => bail!("target error: {message}"),
            other => bail!("unexpected response: {other:?}"),
        }
    }

    fn describe(&self) -> String {
        format!("remote:{}", self.description)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::{Algorithm, Tuner};
    use crate::evaluator::{tune, SimEvaluator};
    use crate::server::TargetServer;
    use crate::sim::ModelId;

    fn spawn_server(model: ModelId, seed: u64) -> (
        std::net::SocketAddr,
        std::thread::JoinHandle<Result<usize>>,
        SearchSpace,
    ) {
        let space = model.space();
        let server = TargetServer::bind(
            "127.0.0.1:0",
            space.clone(),
            Box::new(SimEvaluator::new(model, seed)),
        )
        .unwrap();
        let (addr, handle) = server.spawn().unwrap();
        (addr, handle, space)
    }

    #[test]
    fn end_to_end_remote_tuning() {
        let (addr, handle, space) = spawn_server(ModelId::NcfFp32, 4);
        let mut remote =
            RemoteEvaluator::connect(&addr.to_string(), space.clone()).unwrap();
        assert!(remote.describe().contains("NCF"));
        let mut tuner = Algorithm::Random.build(&space, 1);
        let h = tune(tuner.as_mut(), &mut remote, 10).unwrap();
        assert_eq!(h.len(), 10);
        assert!(h.best().unwrap().value > 0.0);
        // target-side cost travelled back over the wire
        assert!(h.iter().all(|e| e.cost_s >= 0.0));

        remote.shutdown().unwrap();
        let served = handle.join().unwrap().unwrap();
        assert_eq!(served, 10);
    }

    #[test]
    fn pipelined_submit_recv_matches_ids() {
        let (addr, handle, space) = spawn_server(ModelId::NcfFp32, 6);
        let mut remote =
            RemoteEvaluator::connect(&addr.to_string(), space.clone()).unwrap();
        let mut tuner = Algorithm::Random.build(&space, 9);
        let trials = tuner.ask(5);
        assert_eq!(trials.len(), 5);
        for t in &trials {
            remote.submit(t).unwrap();
        }
        let mut got = Vec::new();
        for _ in 0..trials.len() {
            let (id, m) = remote.recv_measurement().unwrap();
            assert!(m.value > 0.0);
            let id = id.expect("daemon echoes trial ids");
            tuner.tell(id, &m);
            got.push(id);
        }
        got.sort_unstable();
        let mut want: Vec<TrialId> = trials.iter().map(|t| t.id).collect();
        want.sort_unstable();
        assert_eq!(got, want, "every in-flight trial answered exactly once");
        remote.shutdown().unwrap();
        let served = handle.join().unwrap().unwrap();
        assert_eq!(served, 5);
    }

    #[test]
    fn connect_all_splits_addresses() {
        let (a1, h1, space) = spawn_server(ModelId::NcfFp32, 1);
        let (a2, h2, _) = spawn_server(ModelId::NcfFp32, 2);
        let addrs = format!("{a1}, {a2}");
        let pool = RemoteEvaluator::connect_all(&addrs, &space).unwrap();
        assert_eq!(pool.len(), 2);
        assert!(RemoteEvaluator::connect_all(" , ", &space).is_err());
        let mut it = pool.into_iter();
        it.next().unwrap().shutdown().unwrap();
        it.next().unwrap().shutdown().unwrap();
        let _ = h1.join();
        let _ = h2.join();
    }

    #[test]
    fn connect_failure_is_clean_error() {
        let space = ModelId::NcfFp32.space();
        assert!(RemoteEvaluator::connect("127.0.0.1:1", space).is_err());
    }

    #[test]
    fn reconnects_after_target_restart() {
        // Kill-and-resume: measure, kill the daemon, restart it on the
        // same port, measure again — the blocking path must redial with
        // backoff instead of failing the session.
        let model = ModelId::NcfFp32;
        let (addr, handle, space) = spawn_server(model, 4);
        let mut remote = RemoteEvaluator::connect(&addr.to_string(), space.clone())
            .unwrap()
            .with_reconnect(20, Duration::from_millis(5));
        let cfg = vec![1, 8, 128, 0, 8];
        assert!(remote.evaluate(&cfg).unwrap() > 0.0);

        // Kill the daemon out from under the evaluator's connection.
        let killer = RemoteEvaluator::connect(&addr.to_string(), space.clone()).unwrap();
        killer.shutdown().unwrap();
        let _ = handle.join();

        // Restart on the very same port, then measure through the stale
        // evaluator: its first send/recv fails, it redials, re-sends.
        let server2 = TargetServer::bind(
            &addr.to_string(),
            space.clone(),
            Box::new(SimEvaluator::new(model, 5)),
        )
        .unwrap();
        let (_, handle2) = server2.spawn().unwrap();
        assert!(remote.evaluate(&cfg).unwrap() > 0.0, "reconnect did not resume");
        assert!(remote.measure(&cfg).unwrap().value > 0.0);

        remote.shutdown().unwrap();
        let served2 = handle2.join().unwrap().unwrap();
        assert_eq!(served2, 2, "both post-restart measurements hit the new daemon");
    }

    #[test]
    fn zero_attempts_restores_fail_fast() {
        let model = ModelId::NcfFp32;
        let (addr, handle, space) = spawn_server(model, 7);
        let mut remote = RemoteEvaluator::connect(&addr.to_string(), space.clone())
            .unwrap()
            .with_reconnect(0, Duration::from_millis(1));
        let killer = RemoteEvaluator::connect(&addr.to_string(), space).unwrap();
        killer.shutdown().unwrap();
        let _ = handle.join();
        let err = remote.evaluate(&vec![1, 8, 128, 0, 8]).unwrap_err();
        assert!(err.to_string().contains("unreachable after 0"), "{err}");
    }
}
