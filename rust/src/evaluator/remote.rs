//! Host-side client for the target daemon: an [`Evaluator`] that sends
//! configurations over TCP and reads back measurements — the optimization
//! framework's half of the paper's Fig. 4 deployment.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;

use anyhow::{bail, Context, Result};

use super::Evaluator;
use crate::server::proto::{
    decode_response, encode_request, Request, Response,
};
use crate::space::{Config, SearchSpace};

pub struct RemoteEvaluator {
    writer: TcpStream,
    reader: BufReader<TcpStream>,
    space: SearchSpace,
    description: String,
}

impl RemoteEvaluator {
    /// Connect to a target daemon and fetch its description.
    pub fn connect(addr: &str, space: SearchSpace) -> Result<RemoteEvaluator> {
        let stream = TcpStream::connect(addr).with_context(|| format!("connecting {addr}"))?;
        // One-line requests/responses: Nagle + delayed-ACK would add ~40 ms
        // per direction (measured 88 ms/eval before this; see EXPERIMENTS.md
        // §Perf).
        stream.set_nodelay(true)?;
        let writer = stream.try_clone()?;
        let reader = BufReader::new(stream);
        let mut me = RemoteEvaluator { writer, reader, space, description: String::new() };
        me.send(&Request::Describe)?;
        match me.recv()? {
            Response::Target { description } => me.description = description,
            other => bail!("unexpected describe response: {other:?}"),
        }
        Ok(me)
    }

    fn send(&mut self, req: &Request) -> Result<()> {
        writeln!(self.writer, "{}", encode_request(req, &self.space))?;
        Ok(())
    }

    fn recv(&mut self) -> Result<Response> {
        let mut line = String::new();
        let n = self.reader.read_line(&mut line)?;
        if n == 0 {
            bail!("target closed the connection");
        }
        decode_response(line.trim_end(), &self.space).map_err(|e| anyhow::anyhow!(e))
    }

    /// Ask the target daemon to shut down.
    pub fn shutdown(mut self) -> Result<()> {
        self.send(&Request::Shutdown)?;
        match self.recv() {
            Ok(Response::Bye) | Err(_) => Ok(()),
            Ok(other) => bail!("unexpected shutdown response: {other:?}"),
        }
    }
}

impl Evaluator for RemoteEvaluator {
    fn evaluate(&mut self, config: &Config) -> Result<f64> {
        self.send(&Request::Evaluate(config.clone()))?;
        match self.recv()? {
            Response::Result { value, .. } => Ok(value),
            Response::Error { message } => bail!("target error: {message}"),
            other => bail!("unexpected response: {other:?}"),
        }
    }

    fn describe(&self) -> String {
        format!("remote:{}", self.description)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::Algorithm;
    use crate::evaluator::{tune, SimEvaluator};
    use crate::server::TargetServer;
    use crate::sim::ModelId;

    #[test]
    fn end_to_end_remote_tuning() {
        let model = ModelId::NcfFp32;
        let space = model.space();
        let server = TargetServer::bind(
            "127.0.0.1:0",
            space.clone(),
            Box::new(SimEvaluator::new(model, 4)),
        )
        .unwrap();
        let (addr, handle) = server.spawn().unwrap();

        let mut remote =
            RemoteEvaluator::connect(&addr.to_string(), space.clone()).unwrap();
        assert!(remote.describe().contains("NCF"));
        let mut tuner = Algorithm::Random.build(&space, 1);
        let h = tune(tuner.as_mut(), &mut remote, 10).unwrap();
        assert_eq!(h.len(), 10);
        assert!(h.best().unwrap().value > 0.0);

        remote.shutdown().unwrap();
        let served = handle.join().unwrap().unwrap();
        assert_eq!(served, 10);
    }

    #[test]
    fn connect_failure_is_clean_error() {
        let space = ModelId::NcfFp32.space();
        assert!(RemoteEvaluator::connect("127.0.0.1:1", space).is_err());
    }
}
