//! Host-side client for the target daemon: an [`Evaluator`] that sends
//! configurations over TCP and reads back measurements — the optimization
//! framework's half of the paper's Fig. 4 deployment.
//!
//! Two usage modes:
//! - **blocking** (`Evaluator` impl): one request/response per call, used
//!   by a `TuningSession` pool with one connection per daemon address
//!   ([`RemoteEvaluator::connect_all`]).
//! - **pipelined** ([`RemoteEvaluator::submit`] + [`RemoteEvaluator::recv_measurement`]):
//!   several trial-tagged requests in flight on one connection; the daemon
//!   answers in completion order and the trial id pairs each response with
//!   its trial.
//!
//! Either way, a daemon's measurement reaches the engine through
//! `Tuner::tell` — with a BO engine that means it *enqueues into the
//! shared surrogate* (`gp::SharedSurrogate`) and is folded into the
//! persistent factor, in arrival order, by the next ask. Tells never
//! block on a concurrent scoring pass, so slow daemons and surrogate
//! scoring overlap freely; `rust/tests/shared_surrogate.rs` pins that
//! shuffled, sharded completion orders condition the factor identically
//! to a serial run fed the same order.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;

use anyhow::{bail, Context, Result};

use super::Evaluator;
use crate::algorithms::{Trial, TrialId};
use crate::history::Measurement;
use crate::server::proto::{decode_response, encode_request, Request, Response};
use crate::space::{Config, SearchSpace};

pub struct RemoteEvaluator {
    writer: TcpStream,
    reader: BufReader<TcpStream>,
    space: SearchSpace,
    description: String,
}

impl RemoteEvaluator {
    /// Connect to a target daemon and fetch its description.
    pub fn connect(addr: &str, space: SearchSpace) -> Result<RemoteEvaluator> {
        let stream = TcpStream::connect(addr).with_context(|| format!("connecting {addr}"))?;
        // One-line requests/responses: Nagle + delayed-ACK would add ~40 ms
        // per direction (measured 88 ms/eval before this; see EXPERIMENTS.md
        // §Perf).
        stream.set_nodelay(true)?;
        let writer = stream.try_clone()?;
        let reader = BufReader::new(stream);
        let mut me = RemoteEvaluator { writer, reader, space, description: String::new() };
        me.send(&Request::Describe)?;
        match me.recv()? {
            Response::Target { description } => me.description = description,
            other => bail!("unexpected describe response: {other:?}"),
        }
        Ok(me)
    }

    /// One connection per comma-separated daemon address — the evaluator
    /// pool for a sharded `TuningSession` (`remote-tune --addr a:1,b:2`).
    pub fn connect_all(addrs: &str, space: &SearchSpace) -> Result<Vec<RemoteEvaluator>> {
        let mut out = Vec::new();
        for addr in addrs.split(',').map(str::trim).filter(|a| !a.is_empty()) {
            out.push(RemoteEvaluator::connect(addr, space.clone())?);
        }
        anyhow::ensure!(!out.is_empty(), "no daemon addresses in '{addrs}'");
        Ok(out)
    }

    fn send(&mut self, req: &Request) -> Result<()> {
        writeln!(self.writer, "{}", encode_request(req, &self.space))?;
        Ok(())
    }

    fn recv(&mut self) -> Result<Response> {
        let mut line = String::new();
        let n = self.reader.read_line(&mut line)?;
        if n == 0 {
            bail!("target closed the connection");
        }
        decode_response(line.trim_end(), &self.space).map_err(|e| anyhow::anyhow!(e))
    }

    /// Pipeline a trial: send its tagged evaluate request without waiting
    /// for the response.
    pub fn submit(&mut self, trial: &Trial) -> Result<()> {
        self.send(&Request::Evaluate { config: trial.config.clone(), trial: Some(trial.id) })
    }

    /// Block for the next completed measurement on this connection.
    /// Returns the trial id the daemon echoed (None for untagged requests)
    /// with the measurement, whose cost is the *target-side* wall clock.
    pub fn recv_measurement(&mut self) -> Result<(Option<TrialId>, Measurement)> {
        match self.recv()? {
            Response::Result { value, cost_s, trial, .. } => {
                Ok((trial, Measurement::new(value).with_cost_s(cost_s)))
            }
            Response::Error { message, .. } => bail!("target error: {message}"),
            other => bail!("unexpected response: {other:?}"),
        }
    }

    /// Ask the target daemon to shut down.
    pub fn shutdown(mut self) -> Result<()> {
        self.send(&Request::Shutdown)?;
        match self.recv() {
            Ok(Response::Bye) | Err(_) => Ok(()),
            Ok(other) => bail!("unexpected shutdown response: {other:?}"),
        }
    }
}

impl Evaluator for RemoteEvaluator {
    fn evaluate(&mut self, config: &Config) -> Result<f64> {
        self.send(&Request::Evaluate { config: config.clone(), trial: None })?;
        match self.recv()? {
            Response::Result { value, .. } => Ok(value),
            Response::Error { message, .. } => bail!("target error: {message}"),
            other => bail!("unexpected response: {other:?}"),
        }
    }

    fn measure(&mut self, config: &Config) -> Result<Measurement> {
        self.send(&Request::Evaluate { config: config.clone(), trial: None })?;
        match self.recv()? {
            Response::Result { value, cost_s, .. } => {
                Ok(Measurement::new(value).with_cost_s(cost_s))
            }
            Response::Error { message, .. } => bail!("target error: {message}"),
            other => bail!("unexpected response: {other:?}"),
        }
    }

    fn describe(&self) -> String {
        format!("remote:{}", self.description)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::{Algorithm, Tuner};
    use crate::evaluator::{tune, SimEvaluator};
    use crate::server::TargetServer;
    use crate::sim::ModelId;

    fn spawn_server(model: ModelId, seed: u64) -> (
        std::net::SocketAddr,
        std::thread::JoinHandle<Result<usize>>,
        SearchSpace,
    ) {
        let space = model.space();
        let server = TargetServer::bind(
            "127.0.0.1:0",
            space.clone(),
            Box::new(SimEvaluator::new(model, seed)),
        )
        .unwrap();
        let (addr, handle) = server.spawn().unwrap();
        (addr, handle, space)
    }

    #[test]
    fn end_to_end_remote_tuning() {
        let (addr, handle, space) = spawn_server(ModelId::NcfFp32, 4);
        let mut remote =
            RemoteEvaluator::connect(&addr.to_string(), space.clone()).unwrap();
        assert!(remote.describe().contains("NCF"));
        let mut tuner = Algorithm::Random.build(&space, 1);
        let h = tune(tuner.as_mut(), &mut remote, 10).unwrap();
        assert_eq!(h.len(), 10);
        assert!(h.best().unwrap().value > 0.0);
        // target-side cost travelled back over the wire
        assert!(h.iter().all(|e| e.cost_s >= 0.0));

        remote.shutdown().unwrap();
        let served = handle.join().unwrap().unwrap();
        assert_eq!(served, 10);
    }

    #[test]
    fn pipelined_submit_recv_matches_ids() {
        let (addr, handle, space) = spawn_server(ModelId::NcfFp32, 6);
        let mut remote =
            RemoteEvaluator::connect(&addr.to_string(), space.clone()).unwrap();
        let mut tuner = Algorithm::Random.build(&space, 9);
        let trials = tuner.ask(5);
        assert_eq!(trials.len(), 5);
        for t in &trials {
            remote.submit(t).unwrap();
        }
        let mut got = Vec::new();
        for _ in 0..trials.len() {
            let (id, m) = remote.recv_measurement().unwrap();
            assert!(m.value > 0.0);
            let id = id.expect("daemon echoes trial ids");
            tuner.tell(id, &m);
            got.push(id);
        }
        got.sort_unstable();
        let mut want: Vec<TrialId> = trials.iter().map(|t| t.id).collect();
        want.sort_unstable();
        assert_eq!(got, want, "every in-flight trial answered exactly once");
        remote.shutdown().unwrap();
        let served = handle.join().unwrap().unwrap();
        assert_eq!(served, 5);
    }

    #[test]
    fn connect_all_splits_addresses() {
        let (a1, h1, space) = spawn_server(ModelId::NcfFp32, 1);
        let (a2, h2, _) = spawn_server(ModelId::NcfFp32, 2);
        let addrs = format!("{a1}, {a2}");
        let pool = RemoteEvaluator::connect_all(&addrs, &space).unwrap();
        assert_eq!(pool.len(), 2);
        assert!(RemoteEvaluator::connect_all(" , ", &space).is_err());
        let mut it = pool.into_iter();
        it.next().unwrap().shutdown().unwrap();
        it.next().unwrap().shutdown().unwrap();
        let _ = h1.join();
        let _ = h2.join();
    }

    #[test]
    fn connect_failure_is_clean_error() {
        let space = ModelId::NcfFp32.space();
        assert!(RemoteEvaluator::connect("127.0.0.1:1", space).is_err());
    }
}
