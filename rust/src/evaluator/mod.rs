//! The "TensorFlow interface" of the paper's framework (Fig. 4): an
//! [`Evaluator`] applies a configuration to the system under test and
//! returns the measured objective. Implementations:
//!
//! - [`SimEvaluator`] — the simulated Intel-TF backend (`sim`),
//! - [`real::RealWorkloadEvaluator`] — actual PJRT executions of the AOT
//!   MLP workload, timed in-process,
//! - [`remote::RemoteEvaluator`] — a TCP client driving a target daemon
//!   (`server`), reproducing the paper's host/target split.
//!
//! `tune()` is the thin serial compatibility loop over the ask/tell API
//! (ask(1) → measure → tell); `session::TuningSession` is the batched,
//! budgeted driver that shards measurements over a pool of evaluators.

pub mod real;
pub mod remote;

pub use real::RealWorkloadEvaluator;
pub use remote::RemoteEvaluator;

use anyhow::Context;

use crate::algorithms::Tuner;
use crate::history::{History, Measurement};
use crate::sim::{ModelId, SimWorkload};
use crate::space::Config;

/// A system under test.
pub trait Evaluator {
    /// Apply `config` and measure the objective (examples/s).
    fn evaluate(&mut self, config: &Config) -> anyhow::Result<f64>;

    /// Apply `config` and return a full [`Measurement`]. The default wraps
    /// [`Evaluator::evaluate`] and stamps the wall-clock cost; targets with
    /// richer telemetry (objective kind, per-op metadata, target-side
    /// timings) override this.
    fn measure(&mut self, config: &Config) -> anyhow::Result<Measurement> {
        let t0 = std::time::Instant::now();
        let value = self.evaluate(config)?;
        Ok(Measurement::new(value).with_cost_s(t0.elapsed().as_secs_f64()))
    }

    /// Human-readable target description (logs, figure titles).
    fn describe(&self) -> String;
}

/// What the tuner maximises (paper §4.1: "Setting [batch] to 1 allows us
/// to obtain latency, while higher values allow us to obtain throughput").
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Objective {
    /// examples/second (the paper's evaluation objective).
    #[default]
    Throughput,
    /// 1 / batch-latency (maximised ⇒ latency minimised). The returned
    /// value is batches/second; callers typically pin batch_size to its
    /// minimum for a pure latency study.
    InverseLatency,
}

impl Objective {
    pub fn parse(s: &str) -> Option<Objective> {
        match s.to_lowercase().as_str() {
            "throughput" | "tp" => Some(Objective::Throughput),
            "latency" | "inverse-latency" | "inv-latency" => Some(Objective::InverseLatency),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Objective::Throughput => "throughput",
            Objective::InverseLatency => "inverse-latency",
        }
    }

    pub fn unit(&self) -> &'static str {
        match self {
            Objective::Throughput => "examples/s",
            Objective::InverseLatency => "batches/s",
        }
    }
}

/// Simulated backend evaluator.
pub struct SimEvaluator {
    workload: SimWorkload,
    pub objective: Objective,
    /// Count of evaluations served (the paper caps runs at 50).
    pub evaluations: usize,
    /// Relative measurement-noise sigma (kept for the tail-latency model
    /// in [`SimEvaluator::measure`]'s metadata).
    sigma: f64,
}

impl SimEvaluator {
    pub fn new(model: ModelId, seed: u64) -> SimEvaluator {
        SimEvaluator::with_sigma(model, seed, crate::sim::noise::DEFAULT_SIGMA)
    }

    pub fn noiseless(model: ModelId) -> SimEvaluator {
        SimEvaluator {
            workload: SimWorkload::noiseless(model),
            objective: Objective::Throughput,
            evaluations: 0,
            sigma: 0.0,
        }
    }

    pub fn with_sigma(model: ModelId, seed: u64, sigma: f64) -> SimEvaluator {
        SimEvaluator {
            workload: SimWorkload::new(model, seed, sigma),
            objective: Objective::Throughput,
            evaluations: 0,
            sigma,
        }
    }

    pub fn with_objective(mut self, objective: Objective) -> SimEvaluator {
        self.objective = objective;
        self
    }

    pub fn model(&self) -> ModelId {
        self.workload.model
    }
}

impl Evaluator for SimEvaluator {
    fn evaluate(&mut self, config: &Config) -> anyhow::Result<f64> {
        self.evaluations += 1;
        match self.objective {
            Objective::Throughput => Ok(self.workload.measure(config)),
            Objective::InverseLatency => {
                // measured throughput / batch = measured batches/s (noise
                // applied through the same stream as throughput mode).
                let tp = self.workload.measure(config);
                Ok(tp / config[crate::space::BATCH] as f64)
            }
        }
    }

    fn measure(&mut self, config: &Config) -> anyhow::Result<Measurement> {
        let t0 = std::time::Instant::now();
        let value = self.evaluate(config)?;
        let mut m = Measurement::new(value)
            .with_objective(self.objective)
            .with_cost_s(t0.elapsed().as_secs_f64());
        // Latency telemetry for multi-objective runs (`--objectives
        // throughput,p99_latency_ms:min`): batch latency derived from
        // the same measured value, with a noise-proportional tail model
        // (a noisier target has a fatter p99). Values stay finite for
        // every positive measurement; a declared-but-absent column is
        // the engine's degradation path, not ours.
        if value > 0.0 {
            let latency_s = match self.objective {
                Objective::Throughput => config[crate::space::BATCH] as f64 / value,
                Objective::InverseLatency => 1.0 / value,
            };
            let latency_ms = latency_s * 1e3;
            // 2.326 = z(0.99): one-sided normal tail at the 99th pct.
            let p99_ms = latency_ms * (1.0 + 2.326 * self.sigma);
            m = m
                .with_metadata("latency_ms", latency_ms)
                .with_metadata("p99_latency_ms", p99_ms);
        }
        Ok(m)
    }

    fn describe(&self) -> String {
        format!("sim:{}:{}", self.workload.model.name(), self.objective.name())
    }
}

/// A pool of `n` independent simulator evaluators over the same model, for
/// a parallel `TuningSession`. Evaluator 0 uses `seed` itself, so a pool
/// of one reproduces a plain `SimEvaluator::with_sigma(model, seed, ..)`
/// run bit for bit; the rest get decorrelated noise streams.
pub fn sim_pool(
    model: ModelId,
    seed: u64,
    sigma: f64,
    objective: Objective,
    n: usize,
) -> Vec<Box<dyn Evaluator + Send>> {
    (0..n.max(1))
        .map(|i| {
            let s = seed.wrapping_add((i as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15));
            Box::new(SimEvaluator::with_sigma(model, s, sigma).with_objective(objective))
                as Box<dyn Evaluator + Send>
        })
        .collect()
}

/// Run `iters` serial tuning iterations of `tuner` against `evaluator` —
/// the compatibility shim over the ask/tell API (one trial in flight at a
/// time, exactly the pre-redesign propose/observe loop). New code and the
/// figure harnesses drive `session::TuningSession` instead.
///
/// A non-finite measurement aborts the run: every engine's bookkeeping
/// (GP standardisation, GA fitness ordering, simplex comparisons) is
/// poisoned by NaN/inf, so failing fast with the offending configuration
/// beats silently corrupting the history.
pub fn tune(
    tuner: &mut dyn Tuner,
    evaluator: &mut dyn Evaluator,
    iters: usize,
) -> anyhow::Result<History> {
    let mut history = History::new();
    for _ in 0..iters {
        let trial = tuner
            .ask(1)
            .pop()
            .with_context(|| format!("engine {} issued no trial", tuner.name()))?;
        let m = evaluator.measure(&trial.config)?;
        anyhow::ensure!(
            m.value.is_finite(),
            "evaluator returned non-finite measurement {} for {:?}",
            m.value,
            trial.config
        );
        tuner.tell(trial.id, &m);
        history.push_trial(trial.id, trial.config, &m);
    }
    Ok(history)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::Algorithm;

    #[test]
    fn tune_smoke_every_algorithm_every_model() {
        for model in ModelId::all() {
            let space = model.space();
            for alg in [Algorithm::Bo, Algorithm::Ga, Algorithm::Nms, Algorithm::Random] {
                let mut tuner = alg.build(&space, 7);
                let mut eval = SimEvaluator::new(model, 7);
                let h = tune(tuner.as_mut(), &mut eval, 15).unwrap();
                assert_eq!(h.len(), 15);
                assert!(h.best().unwrap().value > 0.0);
                for e in h.iter() {
                    assert!(space.contains(&e.config), "{} off grid", alg.name());
                }
            }
        }
    }

    #[test]
    fn tuning_improves_over_first_sample() {
        // On the simulator, 40 iterations of any real algorithm should
        // beat the first random sample (sanity that signal flows).
        let model = ModelId::Resnet50Fp32;
        let space = model.space();
        for alg in Algorithm::all_paper() {
            let mut tuner = alg.build(&space, 3);
            let mut eval = SimEvaluator::new(model, 3);
            let h = tune(tuner.as_mut(), &mut eval, 40).unwrap();
            let first = h.iter().next().unwrap().value;
            let best = h.best().unwrap().value;
            assert!(
                best >= first,
                "{}: best {best} < first {first}",
                alg.name()
            );
        }
    }

    #[test]
    fn objective_parse_round_trip() {
        for o in [Objective::Throughput, Objective::InverseLatency] {
            assert_eq!(Objective::parse(o.name()), Some(o));
        }
        assert_eq!(Objective::parse("latency"), Some(Objective::InverseLatency));
        assert_eq!(Objective::parse("nope"), None);
    }

    #[test]
    fn latency_objective_prefers_small_batches() {
        // Throughput rises with batch; inverse latency falls. Tuning the
        // latency objective must therefore land on a small batch.
        let model = ModelId::Resnet50Fp32;
        let space = model.space();
        let mut tp = SimEvaluator::noiseless(model);
        let mut lat =
            SimEvaluator::noiseless(model).with_objective(Objective::InverseLatency);
        let small = vec![1, 14, 64, 0, 24];
        let big = vec![1, 14, 1024, 0, 24];
        assert!(tp.evaluate(&big).unwrap() > tp.evaluate(&small).unwrap());
        assert!(lat.evaluate(&small).unwrap() > lat.evaluate(&big).unwrap());

        let mut tuner = crate::algorithms::Algorithm::Bo.build(&space, 2);
        let mut eval =
            SimEvaluator::new(model, 2).with_objective(Objective::InverseLatency);
        let h = tune(tuner.as_mut(), &mut eval, 30).unwrap();
        let best = h.best().unwrap();
        assert!(
            best.config[crate::space::BATCH] <= 192,
            "latency tuning picked batch {}",
            best.config[crate::space::BATCH]
        );
    }

    #[test]
    fn raw_trace_dispersion_nms_exceeds_ga() {
        // The paper's Fig. 5 reading: NMS's *per-iteration* throughput
        // oscillates wildly (reflections jump across the space) while
        // GA's trace stays concentrated around its parents.
        use crate::util::stats;
        let model = ModelId::Resnet50Fp32;
        let space = model.space();
        let mut disp = std::collections::HashMap::new();
        for alg in [crate::algorithms::Algorithm::Nms, crate::algorithms::Algorithm::Ga] {
            let mut cv_per_seed = Vec::new();
            for seed in [0u64, 1, 2] {
                let mut t = alg.build(&space, seed);
                let mut e = SimEvaluator::new(model, seed);
                let h = tune(t.as_mut(), &mut e, 50).unwrap();
                let vals = h.values();
                cv_per_seed.push(stats::stddev(&vals) / stats::mean(&vals));
            }
            disp.insert(alg.name(), stats::mean(&cv_per_seed));
        }
        assert!(
            disp["nelder-mead"] > disp["genetic-algorithm"],
            "NMS dispersion {:.3} should exceed GA {:.3}",
            disp["nelder-mead"],
            disp["genetic-algorithm"]
        );
    }

    #[test]
    fn sim_measure_attaches_latency_metadata() {
        let mut eval = SimEvaluator::new(ModelId::NcfFp32, 1);
        let m = eval.measure(&vec![1, 8, 128, 0, 8]).unwrap();
        let get = |k: &str| m.metadata.iter().find(|(n, _)| n == k).map(|&(_, v)| v);
        let lat = get("latency_ms").expect("latency_ms metadata");
        let p99 = get("p99_latency_ms").expect("p99_latency_ms metadata");
        assert!(lat > 0.0 && lat.is_finite());
        assert!(p99 >= lat, "tail latency below the mean: {p99} < {lat}");
        // consistency with the measured value: latency = batch / throughput
        assert!((lat - 128.0 / m.value * 1e3).abs() < 1e-9);
        // noiseless target: p99 equals the mean latency exactly
        let mut quiet = SimEvaluator::noiseless(ModelId::NcfFp32);
        let mq = quiet.measure(&vec![1, 8, 128, 0, 8]).unwrap();
        let get_q = |k: &str| mq.metadata.iter().find(|(n, _)| n == k).map(|&(_, v)| v);
        assert_eq!(get_q("latency_ms"), get_q("p99_latency_ms"));
    }

    #[test]
    fn evaluation_counter_increments() {
        let mut eval = SimEvaluator::new(ModelId::NcfFp32, 1);
        let cfg = vec![1, 8, 128, 0, 8];
        eval.evaluate(&cfg).unwrap();
        eval.evaluate(&cfg).unwrap();
        assert_eq!(eval.evaluations, 2);
    }
}
