//! Real-workload evaluator: the system under test is an actual PJRT
//! executable (the AOT MLP), and the objective is *measured* examples/s.
//!
//! Search space: batch size over the AOT-compiled variants, plus the
//! repetition count is fixed. The threading parameters of the paper do not
//! map onto the single-threaded PJRT CPU client in this container, so this
//! evaluator tunes the batch dimension for real and treats other
//! parameters as no-ops — the point is an end-to-end proof that the tuner,
//! runtime and artifacts compose on a real measurable system (DESIGN.md §2).

use anyhow::Result;

use super::Evaluator;
use crate::runtime::WorkloadRunner;
use crate::space::{Config, ParamDef, SearchSpace};

pub struct RealWorkloadEvaluator {
    runner: WorkloadRunner,
    reps: usize,
    pub evaluations: usize,
}

impl RealWorkloadEvaluator {
    pub fn new(runner: WorkloadRunner, reps: usize) -> RealWorkloadEvaluator {
        RealWorkloadEvaluator { runner, reps, evaluations: 0 }
    }

    /// The tunable space: batch size restricted to the compiled variants.
    /// (Step = gcd of gaps would be wrong; we expose the index instead.)
    pub fn space(&self) -> SearchSpace {
        SearchSpace::new(vec![ParamDef::new(
            "batch_index",
            0,
            self.runner.batches.len() as i64 - 1,
            1,
        )])
    }

    pub fn batch_for(&self, config: &Config) -> i64 {
        self.runner.batches[config[0] as usize]
    }

    pub fn flops_per_example(&self) -> f64 {
        self.runner.flops_per_example
    }
}

impl Evaluator for RealWorkloadEvaluator {
    fn evaluate(&mut self, config: &Config) -> Result<f64> {
        self.evaluations += 1;
        let batch = self.batch_for(config);
        self.runner.measure_throughput(batch, self.reps)
    }

    fn describe(&self) -> String {
        format!("real:pjrt-mlp(batches={:?})", self.runner.batches)
    }
}
