//! The system-under-test substrate: a performance-model simulator of
//! TensorFlow's Intel-CPU backend (Eigen intra/inter-op pools + oneDNN
//! OpenMP runtime) on the paper's Cascade Lake target machine.
//!
//! The paper's testbed (Intel-TF 1.15 + oneDNN on a 48-core Xeon) is not
//! available in this environment; per DESIGN.md §2 this module implements
//! the closest synthetic equivalent that exposes the same black-box
//! response surface f(config) -> throughput to the tuning algorithms.

pub mod engine;
pub mod machine;
pub mod models;
pub mod noise;
pub mod op;

pub use engine::{simulate, ExecReport, ThreadConfig};
pub use machine::Machine;
pub use models::ModelId;
pub use noise::NoiseModel;
pub use op::{Dispatch, Op, OpKind, Precision};

use crate::space::Config;

/// A ready-to-evaluate simulated workload: model graph + machine + noise.
#[derive(Debug, Clone)]
pub struct SimWorkload {
    pub model: ModelId,
    pub machine: Machine,
    ops: Vec<Op>,
    noise: NoiseModel,
}

impl SimWorkload {
    pub fn new(model: ModelId, seed: u64, sigma: f64) -> SimWorkload {
        SimWorkload {
            model,
            machine: Machine::cascade_lake(),
            ops: model.build(),
            noise: NoiseModel::new(seed, sigma),
        }
    }

    /// Default measurement-noise workload.
    pub fn with_default_noise(model: ModelId, seed: u64) -> SimWorkload {
        SimWorkload::new(model, seed, noise::DEFAULT_SIGMA)
    }

    /// Deterministic ground-truth workload (exhaustive sweeps).
    pub fn noiseless(model: ModelId) -> SimWorkload {
        SimWorkload::new(model, 0, 0.0)
    }

    /// Noise-free throughput for a configuration.
    pub fn true_throughput(&self, cfg: &Config) -> f64 {
        let tc = ThreadConfig::from_config(cfg);
        simulate(&self.ops, &self.machine, &tc, self.model.precision()).throughput
    }

    /// One measured evaluation (true throughput + measurement noise).
    pub fn measure(&mut self, cfg: &Config) -> f64 {
        let t = self.true_throughput(cfg);
        self.noise.apply(t)
    }

    /// Full execution report (profiling, tests).
    pub fn report(&self, cfg: &Config) -> ExecReport {
        let tc = ThreadConfig::from_config(cfg);
        simulate(&self.ops, &self.machine, &tc, self.model.precision())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn measure_is_noisy_true_is_not() {
        let mut w = SimWorkload::with_default_noise(ModelId::Resnet50Fp32, 42);
        let cfg = vec![1, 14, 256, 0, 24];
        let t1 = w.true_throughput(&cfg);
        let t2 = w.true_throughput(&cfg);
        assert_eq!(t1, t2);
        let m1 = w.measure(&cfg);
        let m2 = w.measure(&cfg);
        assert_ne!(m1, m2);
        assert!((m1 / t1 - 1.0).abs() < 0.1);
    }

    #[test]
    fn prop_all_models_positive_and_deterministic() {
        for model in ModelId::all() {
            let w = SimWorkload::noiseless(model);
            let space = model.space();
            prop::check(&format!("sim positive {}", model.name()), 40, |rng| {
                let cfg = space.random(rng);
                let t = w.true_throughput(&cfg);
                assert!(t.is_finite() && t > 0.0, "{}: {t} at {cfg:?}", model.name());
                assert_eq!(w.true_throughput(&cfg), t);
            });
        }
    }

    #[test]
    fn prop_noise_seeded_identically_reproduces() {
        let space = ModelId::BertFp32.space();
        prop::check("noisy reproducible", 20, |rng| {
            let seed = rng.next_u64();
            let mut w1 = SimWorkload::with_default_noise(ModelId::BertFp32, seed);
            let mut w2 = SimWorkload::with_default_noise(ModelId::BertFp32, seed);
            let cfg = space.random(rng);
            assert_eq!(w1.measure(&cfg), w2.measure(&cfg));
        });
    }
}
