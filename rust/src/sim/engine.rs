//! The dataflow-graph execution engine: a discrete-event simulation of
//! TensorFlow's inter-op scheduler over the machine and operator models.
//!
//! Mechanisms reproduced (each has a directed unit test):
//!
//! 1. **Inter-op scheduling** — at most `inter_op_parallelism_threads`
//!    operators execute concurrently; ready ops queue (list scheduler).
//! 2. **Per-pool threading** — a oneDNN op uses an OpenMP team of
//!    `OMP_NUM_THREADS`; an Eigen op uses `intra_op` pool threads; each
//!    concurrent inter-op worker instantiates its *own* OpenMP team (the
//!    classic Intel-TF oversubscription trap).
//! 3. **KMP_BLOCKTIME** — after a oneDNN region finishes, its team spins
//!    for `blocktime` ms before sleeping. Parked teams of other inter-op
//!    workers therefore *burn cores* while any op runs (interference grows
//!    with blocktime); with blocktime=0 every region instead pays a team
//!    wake cost. This is the 0-vs-200 tradeoff from the paper's Fig. 6.
//! 4. **Amdahl + roofline op timing** — compute scales with team size;
//!    memory-bound work saturates at `bw_sat_threads`; a team spanning
//!    sockets pays the NUMA multiplier; LLC overflow inflates memory time.
//! 5. **Over-subscription** — total demanded threads beyond physical
//!    cores slow *everything* down superlinearly.
//! 6. **Batch amortisation** — per-op dispatch and per-graph fixed costs
//!    amortise with batch size; throughput saturates, then sags slightly
//!    past the LLC knee.

use super::machine::Machine;
use super::op::{Dispatch, Op, Precision};
use crate::space;
use crate::space::Config;

/// Decoded tuning configuration (paper Table 1 order; see `space`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ThreadConfig {
    pub inter_op: i64,
    pub intra_op: i64,
    pub batch: i64,
    pub blocktime_ms: i64,
    pub omp_threads: i64,
}

impl ThreadConfig {
    pub fn from_config(cfg: &Config) -> ThreadConfig {
        assert_eq!(cfg.len(), 5, "expected 5-parameter configuration");
        ThreadConfig {
            inter_op: cfg[space::INTER_OP],
            intra_op: cfg[space::INTRA_OP],
            batch: cfg[space::BATCH],
            blocktime_ms: cfg[space::BLOCKTIME],
            omp_threads: cfg[space::OMP_THREADS],
        }
    }
}

/// One op's execution record (profiling / the `tftune profile` command).
#[derive(Debug, Clone)]
pub struct TraceEvent {
    pub op: String,
    pub start_s: f64,
    pub end_s: f64,
    /// Team size the op ran with.
    pub threads: f64,
    /// Over-subscription slowdown applied at dispatch.
    pub slowdown: f64,
}

/// Execution report for one batch through the graph.
#[derive(Debug, Clone)]
pub struct ExecReport {
    /// End-to-end latency of one batch, seconds.
    pub latency_s: f64,
    /// Throughput, examples/second.
    pub throughput: f64,
    /// Peak concurrent thread demand observed.
    pub peak_demand: f64,
    /// Total over-subscription-weighted busy time (profiling aid).
    pub busy_s: f64,
    /// Per-op schedule (start/end/threads/slowdown), dispatch order.
    pub trace: Vec<TraceEvent>,
}

/// Timing for a single op given the current contention snapshot.
#[allow(clippy::too_many_arguments)]
fn op_duration(
    op: &Op,
    mach: &Machine,
    tc: &ThreadConfig,
    precision: Precision,
    slowdown: f64,
) -> f64 {
    let team = match op.dispatch {
        Dispatch::OneDnn => tc.omp_threads as f64,
        Dispatch::Eigen => tc.intra_op as f64,
        Dispatch::Serial => 1.0,
    }
    .max(1.0);

    let peak = mach.peak_flops_core * precision.peak_multiplier();
    let flops = op.flops(tc.batch);
    let bytes = op.bytes(tc.batch) * precision.bytes_multiplier();

    // Amdahl split: the serial fraction runs on one thread at fp32 peak.
    // Compute scaling caps at the physical core count (SMT siblings share
    // FMA ports — see Machine::compute_threads).
    let p = op.parallel_frac;
    let comp_team = mach.compute_threads(team);
    let comp_par = flops * p / (peak * comp_team);
    let comp_ser = flops * (1.0 - p) / peak;

    // Memory: bandwidth model with saturation + NUMA + LLC pressure.
    let bw1 = mach.mem_bw / mach.bw_sat_threads; // one thread's share
    let mem_speed = bw1 * mach.mem_speedup(team);
    let mut mem = bytes / mem_speed * mach.numa_mult(team);
    if bytes > mach.llc_bytes {
        mem *= 1.18; // streaming from DRAM without reuse
    }

    // Roofline: compute and memory overlap; serial part does not.
    let work = comp_par.max(mem) + comp_ser;

    // Parallel-region overheads (mechanism 3).
    let regions = op.regions as f64;
    let mut overhead = regions * (mach.fork_base_s + mach.fork_per_thread_s * team);
    if op.dispatch == Dispatch::OneDnn {
        if tc.blocktime_ms == 0 {
            // team sleeps after every region -> wake per region
            overhead += regions * mach.wake_s;
        } else {
            // team was possibly asleep only at op start
            overhead += mach.wake_s;
        }
    }

    (work + overhead) * slowdown + mach.dispatch_s
}

/// Thread demand contributed by a *running* op.
fn running_demand(op: &Op, tc: &ThreadConfig) -> f64 {
    match op.dispatch {
        Dispatch::OneDnn => tc.omp_threads as f64,
        Dispatch::Eigen => tc.intra_op as f64,
        Dispatch::Serial => 1.0,
    }
}

/// Fraction of a team's parked/gap time spent spinning rather than
/// sleeping: grows with KMP_BLOCKTIME (ms scale; park intervals are
/// ~100 ms, so blocktime >= 100 means effectively always spinning).
fn spin_frac(tc: &ThreadConfig) -> f64 {
    (tc.blocktime_ms as f64 / 100.0).min(1.0)
}

/// While an op executes, its own team is not computing during region gaps
/// (master-thread serial stretches, load imbalance at region joins); with
/// blocktime > 0 those threads spin and steal cores from *other* running
/// ops. Measured oneDNN traces put this gap time around a third of op
/// wall-time for short-region primitives.
const SPIN_GAP_FRACTION: f64 = 0.35;

/// Thread demand from spinning OpenMP threads (mechanism 3).
///
/// Two sources: (a) *parked* teams — inter-op workers that own a team but
/// are not currently running a oneDNN op — spin at full team width;
/// (b) *active* teams spin during their own ops' region gaps. Both scale
/// with `spin_frac` and vanish at blocktime = 0 (where the cost shows up
/// as per-region wake latency instead — see `op_duration`).
fn spinning_demand(parked_teams: f64, active_onednn: f64, tc: &ThreadConfig) -> f64 {
    if tc.blocktime_ms == 0 {
        return 0.0;
    }
    let team = tc.omp_threads as f64;
    let gap_spinners = if active_onednn > 1.0 {
        // only interferes when there is a concurrent victim
        active_onednn * team * SPIN_GAP_FRACTION
    } else {
        0.0
    };
    (parked_teams * team + gap_spinners) * spin_frac(tc)
}

/// Simulate one batch execution of `ops` and return the report.
///
/// Deterministic: no randomness lives here (noise is applied by the
/// evaluator on top). Ops must form a DAG via `preds`.
pub fn simulate(ops: &[Op], mach: &Machine, tc: &ThreadConfig, precision: Precision) -> ExecReport {
    assert!(tc.inter_op >= 1 && tc.intra_op >= 1 && tc.omp_threads >= 1);
    assert!(tc.batch >= 1, "batch must be positive");
    let n = ops.len();
    assert!(n > 0, "empty graph");

    let mut remaining_preds: Vec<usize> = ops.iter().map(|o| o.preds.len()).collect();
    let mut succs: Vec<Vec<usize>> = vec![Vec::new(); n];
    for (i, op) in ops.iter().enumerate() {
        for &p in &op.preds {
            assert!(p < n, "op {i} has out-of-range pred {p}");
            succs[p].push(i);
        }
    }

    // Ready queue in op-index order (TF uses FIFO-ish; order only matters
    // for ties). Running: (finish_time, op index).
    let mut ready: Vec<usize> =
        (0..n).filter(|&i| remaining_preds[i] == 0).collect();
    assert!(!ready.is_empty(), "graph has no source ops (cycle?)");
    let mut running: Vec<(f64, usize)> = Vec::new();
    let mut done = 0usize;
    let mut now = 0.0f64;
    let mut peak_demand = 0.0f64;
    let mut busy_s = 0.0f64;
    let mut trace: Vec<TraceEvent> = Vec::with_capacity(n);

    // Teams get created lazily; track how many inter-op workers have run a
    // oneDNN op so far (those own parkable OpenMP teams).
    let mut teams_created = 0.0f64;

    while done < n {
        // Dispatch as many ready ops as inter-op slots allow.
        while !ready.is_empty() && (running.len() as i64) < tc.inter_op {
            let op_idx = ready.remove(0);
            let op = &ops[op_idx];

            if op.dispatch == Dispatch::OneDnn {
                teams_created = (teams_created + 1.0).min(tc.inter_op as f64);
            }

            // Contention snapshot: all running demands + this op + spinners.
            let active_onednn =
                running.iter().filter(|(_, i)| ops[*i].dispatch == Dispatch::OneDnn).count()
                    as f64
                    + if op.dispatch == Dispatch::OneDnn { 1.0 } else { 0.0 };
            let parked = (teams_created - active_onednn).max(0.0);
            let demand: f64 = running.iter().map(|(_, i)| running_demand(&ops[*i], tc)).sum::<f64>()
                + running_demand(op, tc)
                + spinning_demand(parked, active_onednn, tc);
            peak_demand = peak_demand.max(demand);
            let slowdown = mach.oversub_slowdown(demand);

            let dur = op_duration(op, mach, tc, precision, slowdown);
            busy_s += dur;
            trace.push(TraceEvent {
                op: op.name.clone(),
                start_s: now,
                end_s: now + dur,
                threads: running_demand(op, tc),
                slowdown,
            });
            running.push((now + dur, op_idx));
        }

        // Advance to the earliest finish.
        let (min_pos, _) = running
            .iter()
            .enumerate()
            .min_by(|a, b| a.1 .0.partial_cmp(&b.1 .0).unwrap())
            .expect("deadlock: nothing running but ops remain");
        let (t, finished) = running.swap_remove(min_pos);
        now = t;
        done += 1;
        for &s in &succs[finished] {
            remaining_preds[s] -= 1;
            if remaining_preds[s] == 0 {
                ready.push(s);
            }
        }
    }

    // Per-graph fixed overhead (session/feed-fetch) before the next batch.
    let latency = now + 120e-6;
    ExecReport {
        latency_s: latency,
        throughput: tc.batch as f64 / latency,
        peak_demand,
        busy_s,
        trace,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::op::OpKind;

    fn mach() -> Machine {
        Machine::cascade_lake()
    }

    fn tc(inter: i64, intra: i64, batch: i64, bt: i64, omp: i64) -> ThreadConfig {
        ThreadConfig { inter_op: inter, intra_op: intra, batch, blocktime_ms: bt, omp_threads: omp }
    }

    fn conv(name: &str, preds: Vec<usize>) -> Op {
        Op::new(name, OpKind::Conv2d, Dispatch::OneDnn, 2e8, 4e5, 2e6, 0.97, 8, preds)
    }

    fn eigen_op(name: &str, preds: Vec<usize>) -> Op {
        Op::new(name, OpKind::Softmax, Dispatch::Eigen, 5e7, 8e5, 0.0, 0.9, 4, preds)
    }

    #[test]
    fn chain_is_sequential() {
        // latency(chain of 2) ~ 2 * latency(1 op), so throughput halves.
        let one = vec![conv("a", vec![])];
        let two = vec![conv("a", vec![]), conv("b", vec![0])];
        let c = tc(1, 1, 64, 0, 24);
        let r1 = simulate(&one, &mach(), &c, Precision::Fp32);
        let r2 = simulate(&two, &mach(), &c, Precision::Fp32);
        assert!(r2.latency_s > 1.8 * r1.latency_s);
    }

    #[test]
    fn omp_threads_speed_up_onednn_graph() {
        let ops = vec![conv("a", vec![]), conv("b", vec![0])];
        let slow = simulate(&ops, &mach(), &tc(1, 1, 64, 0, 1), Precision::Fp32);
        let fast = simulate(&ops, &mach(), &tc(1, 1, 64, 0, 24), Precision::Fp32);
        assert!(
            fast.throughput > 5.0 * slow.throughput,
            "omp 24 {:.1} vs omp 1 {:.1}",
            fast.throughput,
            slow.throughput
        );
    }

    #[test]
    fn intra_op_is_inert_for_pure_onednn_graph() {
        // Mechanism behind the paper's §4.3 ResNet50-INT8 observation.
        let ops = vec![conv("a", vec![]), conv("b", vec![0])];
        let lo = simulate(&ops, &mach(), &tc(1, 1, 64, 0, 24), Precision::Fp32);
        let hi = simulate(&ops, &mach(), &tc(1, 56, 64, 0, 24), Precision::Fp32);
        assert!((lo.throughput - hi.throughput).abs() < 1e-9);
    }

    #[test]
    fn intra_op_matters_for_eigen_ops() {
        let ops = vec![eigen_op("s", vec![])];
        let lo = simulate(&ops, &mach(), &tc(1, 1, 64, 0, 4), Precision::Fp32);
        let hi = simulate(&ops, &mach(), &tc(1, 24, 64, 0, 4), Precision::Fp32);
        assert!(hi.throughput > 1.5 * lo.throughput);
    }

    #[test]
    fn blocktime_zero_wins_with_parallel_inter_op() {
        // Two parallel oneDNN branches, inter_op=2: the parked team's
        // spinning with blocktime=200 steals cores.
        let ops = vec![
            conv("a1", vec![]),
            conv("a2", vec![]),
            conv("b1", vec![0]),
            conv("b2", vec![1]),
            conv("c1", vec![2]),
            conv("c2", vec![3]),
        ];
        let bt0 = simulate(&ops, &mach(), &tc(2, 1, 64, 0, 36), Precision::Fp32);
        let bt200 = simulate(&ops, &mach(), &tc(2, 1, 64, 200, 36), Precision::Fp32);
        assert!(
            bt0.throughput > bt200.throughput,
            "bt0 {:.1} <= bt200 {:.1}",
            bt0.throughput,
            bt200.throughput
        );
    }

    #[test]
    fn blocktime_nonzero_wins_single_stream_many_regions() {
        // inter_op=1: no parked teams, so blocktime only saves wake costs.
        let mut op = conv("a", vec![]);
        op.regions = 200;
        op.flops_per_ex = 1e6; // short regions -> overhead-dominated
        let ops = vec![op];
        let bt0 = simulate(&ops, &mach(), &tc(1, 1, 64, 0, 24), Precision::Fp32);
        let bt50 = simulate(&ops, &mach(), &tc(1, 1, 64, 50, 24), Precision::Fp32);
        assert!(bt50.throughput > bt0.throughput);
    }

    #[test]
    fn oversubscription_hurts() {
        // 4 concurrent teams of 56 threads = demand 224 >> 96 hw threads.
        let ops = vec![conv("a", vec![]), conv("b", vec![]), conv("c", vec![]), conv("d", vec![])];
        let sane = simulate(&ops, &mach(), &tc(4, 1, 64, 0, 12), Precision::Fp32);
        let crazy = simulate(&ops, &mach(), &tc(4, 1, 64, 0, 56), Precision::Fp32);
        assert!(sane.throughput > crazy.throughput);
        assert!(crazy.peak_demand > 200.0);
    }

    #[test]
    fn int8_faster_than_fp32() {
        let ops = vec![conv("a", vec![]), conv("b", vec![0])];
        let c = tc(1, 1, 64, 0, 24);
        let f = simulate(&ops, &mach(), &c, Precision::Fp32);
        let i = simulate(&ops, &mach(), &c, Precision::Int8);
        assert!(i.throughput > 1.5 * f.throughput);
    }

    #[test]
    fn batch_amortises_overheads() {
        let ops = vec![conv("a", vec![]), conv("b", vec![0])];
        let c1 = simulate(&ops, &mach(), &tc(1, 1, 1, 0, 24), Precision::Fp32);
        let c64 = simulate(&ops, &mach(), &tc(1, 1, 64, 0, 24), Precision::Fp32);
        // per-example rate much better at batch 64
        assert!(c64.throughput > 2.5 * c1.throughput);
    }

    #[test]
    fn parallel_branches_benefit_from_inter_op() {
        let ops = vec![conv("a", vec![]), conv("b", vec![]), conv("j", vec![0, 1])];
        let seq = simulate(&ops, &mach(), &tc(1, 1, 64, 0, 12), Precision::Fp32);
        let par = simulate(&ops, &mach(), &tc(2, 1, 64, 0, 12), Precision::Fp32);
        assert!(par.throughput > 1.2 * seq.throughput);
    }

    #[test]
    fn trace_is_consistent_schedule() {
        let ops = vec![conv("a", vec![]), conv("b", vec![]), eigen_op("s", vec![0, 1])];
        let c = tc(2, 8, 64, 0, 12);
        let r = simulate(&ops, &mach(), &c, Precision::Fp32);
        assert_eq!(r.trace.len(), 3);
        // every event within [0, latency], end > start
        for ev in &r.trace {
            assert!(ev.start_s >= 0.0 && ev.end_s <= r.latency_s);
            assert!(ev.end_s > ev.start_s);
            assert!(ev.slowdown >= 1.0);
        }
        // the join op must start after both branches end
        let join = r.trace.iter().find(|e| e.op == "s").unwrap();
        for branch in r.trace.iter().filter(|e| e.op != "s") {
            assert!(join.start_s >= branch.end_s - 1e-12);
        }
        // with inter_op=2 the two branches overlap
        let a = r.trace.iter().find(|e| e.op == "a").unwrap();
        let b = r.trace.iter().find(|e| e.op == "b").unwrap();
        assert!(a.start_s < b.end_s && b.start_s < a.end_s, "branches did not overlap");
    }

    #[test]
    fn deterministic() {
        let ops = vec![conv("a", vec![]), eigen_op("s", vec![0])];
        let c = tc(2, 8, 128, 30, 16);
        let r1 = simulate(&ops, &mach(), &c, Precision::Fp32);
        let r2 = simulate(&ops, &mach(), &c, Precision::Fp32);
        assert_eq!(r1.throughput, r2.throughput);
    }

    #[test]
    #[should_panic]
    fn rejects_zero_batch() {
        let ops = vec![conv("a", vec![])];
        simulate(&ops, &mach(), &tc(1, 1, 0, 0, 1), Precision::Fp32);
    }
}
