//! The six Intel Model Zoo workloads the paper tunes (§4.1), as op-level
//! dataflow graphs for the simulator.
//!
//! Repeated primitives are aggregated into stage-level ops (a ResNet stage
//! op stands for its ~10 convolutions; `regions` preserves the true
//! parallel-region count, which is what the KMP_BLOCKTIME mechanism feels).
//! FLOP counts come from the models' published per-example numbers;
//! byte counts are activation+weight traffic estimates. What must be
//! faithful is each model's *sensitivity structure* (which parameters move
//! its throughput), which is driven by the oneDNN/Eigen dispatch mix,
//! region granularity, arithmetic intensity and batch range — see
//! DESIGN.md §6.

use super::op::{Dispatch, Op, OpKind, Precision};
use crate::space::{threading_space, SearchSpace};

/// The six benchmark models (paper Fig. 5).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ModelId {
    SsdMobilenetFp32,
    Resnet50Fp32,
    Resnet50Int8,
    TransformerLtFp32,
    BertFp32,
    NcfFp32,
}

impl ModelId {
    pub fn all() -> [ModelId; 6] {
        [
            ModelId::SsdMobilenetFp32,
            ModelId::Resnet50Fp32,
            ModelId::Resnet50Int8,
            ModelId::TransformerLtFp32,
            ModelId::BertFp32,
            ModelId::NcfFp32,
        ]
    }

    pub fn name(&self) -> &'static str {
        match self {
            ModelId::SsdMobilenetFp32 => "SSD-MobileNet-FP32",
            ModelId::Resnet50Fp32 => "ResNet50-FP32",
            ModelId::Resnet50Int8 => "ResNet50-INT8",
            ModelId::TransformerLtFp32 => "Transformer-LT-FP32",
            ModelId::BertFp32 => "BERT-FP32",
            ModelId::NcfFp32 => "NCF-FP32",
        }
    }

    pub fn parse(s: &str) -> Option<ModelId> {
        let lower = s.to_lowercase();
        ModelId::all()
            .into_iter()
            .find(|m| m.name().to_lowercase() == lower || m.short_name() == lower)
    }

    pub fn short_name(&self) -> &'static str {
        match self {
            ModelId::SsdMobilenetFp32 => "ssd-mobilenet",
            ModelId::Resnet50Fp32 => "resnet50-fp32",
            ModelId::Resnet50Int8 => "resnet50-int8",
            ModelId::TransformerLtFp32 => "transformer-lt",
            ModelId::BertFp32 => "bert",
            ModelId::NcfFp32 => "ncf",
        }
    }

    pub fn precision(&self) -> Precision {
        match self {
            ModelId::Resnet50Int8 => Precision::Int8,
            _ => Precision::Fp32,
        }
    }

    /// The paper's per-model batch-size range (Table 1).
    pub fn batch_range(&self) -> (i64, i64, i64) {
        match self {
            ModelId::NcfFp32 => (64, 256, 64),
            ModelId::BertFp32 => (32, 64, 32),
            _ => (64, 1024, 64),
        }
    }

    /// The full 5-parameter tuning space for this model (Table 1).
    pub fn space(&self) -> SearchSpace {
        let (lo, hi, step) = self.batch_range();
        threading_space(lo, hi, step)
    }

    /// Build the op graph.
    pub fn build(&self) -> Vec<Op> {
        match self {
            ModelId::SsdMobilenetFp32 => ssd_mobilenet(),
            ModelId::Resnet50Fp32 | ModelId::Resnet50Int8 => resnet50(),
            ModelId::TransformerLtFp32 => transformer_lt(),
            ModelId::BertFp32 => bert(),
            ModelId::NcfFp32 => ncf(),
        }
    }
}

// Helper constructors -------------------------------------------------------

fn dnn(name: &str, kind: OpKind, gflops: f64, mb_ex: f64, mb_fixed: f64, p: f64, regions: u32, preds: Vec<usize>) -> Op {
    Op::new(name, kind, Dispatch::OneDnn, gflops * 1e9, mb_ex * 1e6, mb_fixed * 1e6, p, regions, preds)
}

fn eig(name: &str, kind: OpKind, gflops: f64, mb_ex: f64, p: f64, regions: u32, preds: Vec<usize>) -> Op {
    Op::new(name, kind, Dispatch::Eigen, gflops * 1e9, mb_ex * 1e6, 0.0, p, regions, preds)
}

fn ser(name: &str, gflops: f64, mb_ex: f64, preds: Vec<usize>) -> Op {
    Op::new(name, OpKind::Bookkeeping, Dispatch::Serial, gflops * 1e9, mb_ex * 1e6, 0.0, 0.0, 1, preds)
}

// Model graphs ---------------------------------------------------------------

/// ResNet50 v1.5 inference, ~4 GFLOP/image. Practically every hot op is a
/// oneDNN convolution -> intra_op is inert (paper §4.3), OMP_NUM_THREADS
/// dominates. A pure chain: inter_op buys nothing except over-subscription
/// headroom for the spinning-team interference term.
fn resnet50() -> Vec<Op> {
    vec![
        dnn("stem_conv7x7", OpKind::Conv2d, 0.24, 3.1, 0.04, 0.985, 2, vec![]),
        dnn("res2_convs", OpKind::Conv2d, 0.68, 9.2, 0.9, 0.985, 10, vec![0]),
        dnn("res3_convs", OpKind::Conv2d, 0.85, 6.9, 4.5, 0.985, 13, vec![1]),
        dnn("res4_convs", OpKind::Conv2d, 1.30, 5.2, 28.0, 0.985, 19, vec![2]),
        dnn("res5_convs", OpKind::Conv2d, 0.80, 2.1, 60.0, 0.985, 10, vec![3]),
        eig("global_pool", OpKind::Pool, 0.0002, 0.4, 0.9, 1, vec![4]),
        dnn("fc1000", OpKind::MatMul, 0.004, 0.02, 8.2, 0.95, 1, vec![5]),
        ser("softmax_out", 0.00001, 0.008, vec![6]),
    ]
}

/// SSD-MobileNet v1, ~2.5 GFLOP/image but dominated by low-arithmetic-
/// intensity depthwise convolutions (memory-bound, many short regions) and
/// a 6-way parallel detection head -> inter_op > 1 genuinely helps, and the
/// short regions make the wake/spin tradeoff visible.
fn ssd_mobilenet() -> Vec<Op> {
    let mut ops = vec![
        dnn("backbone_std_convs", OpKind::Conv2d, 0.95, 7.5, 6.5, 0.98, 14, vec![]),
        dnn("backbone_dw_convs", OpKind::DepthwiseConv, 0.35, 11.0, 1.2, 0.93, 26, vec![0]),
    ];
    // 6 SSD feature heads in parallel off the backbone.
    for i in 0..6 {
        ops.push(dnn(
            &format!("head{i}_conv"),
            OpKind::Conv2d,
            0.18,
            1.4,
            2.2,
            0.95,
            4,
            vec![1],
        ));
    }
    let head_ids: Vec<usize> = (2..8).collect();
    ops.push(eig("box_decode", OpKind::Eltwise, 0.01, 1.8, 0.85, 3, head_ids.clone()));
    ops.push(eig("nms_postproc", OpKind::Eltwise, 0.006, 0.9, 0.55, 2, vec![8]));
    ops
}

/// Transformer-LT (translation): 6-layer encoder / 6-layer decoder with a
/// beam-search loop. A genuinely *mixed* graph: oneDNN matmuls interleave
/// with Eigen softmax/layernorm at similar magnitudes, so intra_op and
/// OMP_NUM_THREADS must share the cores — a rugged, interaction-heavy
/// landscape (the one where GA wins in Fig. 5).
fn transformer_lt() -> Vec<Op> {
    vec![
        eig("embed_src", OpKind::Embedding, 0.002, 2.4, 0.8, 2, vec![]),
        dnn("enc_qkv_matmuls", OpKind::MatMul, 1.9, 3.0, 25.0, 0.96, 24, vec![0]),
        eig("enc_softmax_norm", OpKind::Softmax, 0.35, 6.5, 0.88, 24, vec![1]),
        dnn("enc_ffn_matmuls", OpKind::MatMul, 3.8, 4.2, 50.0, 0.97, 12, vec![2]),
        eig("dec_embed", OpKind::Embedding, 0.002, 1.8, 0.8, 2, vec![3]),
        dnn("dec_qkv_matmuls", OpKind::MatMul, 2.3, 3.4, 34.0, 0.96, 36, vec![4]),
        eig("dec_softmax_norm", OpKind::Softmax, 0.45, 7.0, 0.88, 36, vec![5]),
        dnn("dec_ffn_matmuls", OpKind::MatMul, 4.4, 4.6, 50.0, 0.97, 18, vec![6]),
        eig("beam_search", OpKind::Eltwise, 0.09, 3.2, 0.45, 30, vec![7]),
        ser("detokenize", 0.0005, 0.3, vec![8]),
    ]
}

/// BERT-base (seq 128), ~11 GFLOP/sequence of big dense matmuls with heavy
/// activation traffic. Bandwidth saturation plus the NUMA penalty past one
/// socket puts the OMP optimum *inside* the range (~24); the narrow batch
/// range [32, 64] leaves a sharp ridge that local refinement (NMS) finds
/// better than global samplers — the paper's BERT anomaly.
fn bert() -> Vec<Op> {
    let mut ops = vec![eig("embed_lookup", OpKind::Embedding, 0.004, 4.0, 0.8, 3, vec![])];
    // 12 encoder layers, aggregated in 4 groups of 3 for graph simplicity.
    for g in 0..4 {
        let pred = ops.len() - 1;
        ops.push(dnn(
            &format!("layers{g}_attn_matmuls"),
            OpKind::BatchMatMul,
            1.05,
            30.0,
            21.0,
            0.965,
            27,
            vec![pred],
        ));
        ops.push(eig(
            &format!("layers{g}_softmax_ln"),
            OpKind::Softmax,
            0.16,
            14.0,
            0.9,
            18,
            vec![pred + 1],
        ));
        ops.push(dnn(
            &format!("layers{g}_ffn_matmuls"),
            OpKind::MatMul,
            1.70,
            18.0,
            57.0,
            0.97,
            9,
            vec![pred + 2],
        ));
    }
    let last = ops.len() - 1;
    ops.push(dnn("pooler_matmul", OpKind::MatMul, 0.01, 0.1, 2.4, 0.9, 1, vec![last]));
    ops
}

/// Neural Collaborative Filtering: embedding gathers (memory-bound, Eigen)
/// feeding a tiny MLP. Per-example work is ~0.3 MFLOP, so throughput is
/// enormous and dominated by dispatch overhead + memory streams; OMP
/// threads barely matter, intra_op and batch dominate — a smooth, gently
/// unimodal surface (where BO shines in Fig. 5).
fn ncf() -> Vec<Op> {
    vec![
        eig("user_embed", OpKind::Embedding, 0.00004, 0.09, 0.8, 1, vec![]),
        eig("item_embed", OpKind::Embedding, 0.00004, 0.09, 0.8, 1, vec![]),
        ser("concat", 0.0000008, 0.002, vec![0, 1]),
        dnn("mlp_fc256", OpKind::MatMul, 0.00013, 0.003, 0.26, 0.9, 1, vec![2]),
        dnn("mlp_fc128", OpKind::MatMul, 0.000066, 0.0015, 0.13, 0.9, 1, vec![3]),
        dnn("mlp_fc64", OpKind::MatMul, 0.000016, 0.0008, 0.033, 0.85, 1, vec![4]),
        ser("sigmoid_out", 0.0000002, 0.0004, vec![5]),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::engine::{simulate, ThreadConfig};
    use crate::sim::machine::Machine;
    use crate::sim::op::Dispatch;

    fn run(m: ModelId, tc: ThreadConfig) -> f64 {
        simulate(&m.build(), &Machine::cascade_lake(), &tc, m.precision()).throughput
    }

    fn base_tc(m: ModelId) -> ThreadConfig {
        let (lo, hi, _) = m.batch_range();
        ThreadConfig { inter_op: 1, intra_op: 14, batch: (lo + hi) / 2, blocktime_ms: 0, omp_threads: 24 }
    }

    #[test]
    fn graphs_are_dags_with_valid_preds() {
        for m in ModelId::all() {
            let ops = m.build();
            assert!(!ops.is_empty());
            for (i, op) in ops.iter().enumerate() {
                for &p in &op.preds {
                    assert!(p < i, "{}: op {i} pred {p} not topologically earlier", m.name());
                }
            }
        }
    }

    #[test]
    fn all_models_simulate_positive_throughput() {
        for m in ModelId::all() {
            let t = run(m, base_tc(m));
            assert!(t > 0.0, "{} throughput {t}", m.name());
        }
    }

    #[test]
    fn throughput_magnitudes_plausible() {
        // Orders of magnitude only (simulator, not testbed): images/s for
        // vision models, sequences/s for language, 100k+ ex/s for NCF.
        let rn50 = run(ModelId::Resnet50Fp32, base_tc(ModelId::Resnet50Fp32));
        assert!((50.0..3000.0).contains(&rn50), "rn50 {rn50}");
        let bert = run(ModelId::BertFp32, base_tc(ModelId::BertFp32));
        assert!((5.0..500.0).contains(&bert), "bert {bert}");
        let ncf = run(ModelId::NcfFp32, base_tc(ModelId::NcfFp32));
        assert!(ncf > 30_000.0, "ncf {ncf}");
        assert!(rn50 > bert, "resnet should outrun bert");
        assert!(ncf > 20.0 * rn50, "ncf should dwarf resnet");
    }

    #[test]
    fn int8_beats_fp32_resnet() {
        let f = run(ModelId::Resnet50Fp32, base_tc(ModelId::Resnet50Fp32));
        let i = run(ModelId::Resnet50Int8, base_tc(ModelId::Resnet50Int8));
        assert!(i > 1.5 * f, "int8 {i} vs fp32 {f}");
    }

    #[test]
    fn resnet_int8_insensitive_to_intra_op() {
        // The paper's §4.3 sweep observation, end-to-end.
        let mut tc = base_tc(ModelId::Resnet50Int8);
        let lo = run(ModelId::Resnet50Int8, tc);
        tc.intra_op = 56;
        let hi = run(ModelId::Resnet50Int8, tc);
        let rel = (hi - lo).abs() / lo;
        assert!(rel < 0.02, "intra_op moved int8 resnet by {rel}");
    }

    #[test]
    fn transformer_sensitive_to_both_pools() {
        let m = ModelId::TransformerLtFp32;
        let mut tc = base_tc(m);
        tc.intra_op = 1;
        let lo_intra = run(m, tc);
        tc.intra_op = 24;
        let hi_intra = run(m, tc);
        assert!(hi_intra > 1.1 * lo_intra, "intra should matter for transformer");
        let mut tc2 = base_tc(m);
        tc2.omp_threads = 1;
        let lo_omp = run(m, tc2);
        tc2.omp_threads = 24;
        let hi_omp = run(m, tc2);
        assert!(hi_omp > 1.5 * lo_omp, "omp should matter for transformer");
    }

    #[test]
    fn bert_omp_optimum_is_interior() {
        // Compute scaling caps at the 48 physical cores while SMT
        // over-subscription and NUMA bite beyond — the OMP optimum sits
        // inside the [1, 56] range (the narrow ridge NMS refines well).
        let m = ModelId::BertFp32;
        let mut tc = base_tc(m);
        tc.omp_threads = 8;
        let low = run(m, tc);
        tc.omp_threads = 44;
        let mid = run(m, tc);
        tc.omp_threads = 56;
        let high = run(m, tc);
        assert!(mid > low && mid > high, "bert omp curve: {low} {mid} {high}");
    }

    #[test]
    fn ncf_omp_nearly_irrelevant_intra_matters() {
        let m = ModelId::NcfFp32;
        let mut tc = base_tc(m);
        tc.omp_threads = 1;
        let omp_lo = run(m, tc);
        tc.omp_threads = 48;
        let omp_hi = run(m, tc);
        let omp_rel = (omp_hi - omp_lo).abs() / omp_lo;
        let mut tc2 = base_tc(m);
        tc2.intra_op = 1;
        let intra_lo = run(m, tc2);
        tc2.intra_op = 16;
        let intra_hi = run(m, tc2);
        let intra_rel = (intra_hi - intra_lo) / intra_lo;
        assert!(intra_rel > 2.0 * omp_rel, "intra {intra_rel} vs omp {omp_rel}");
    }

    #[test]
    fn ssd_benefits_from_inter_op() {
        let m = ModelId::SsdMobilenetFp32;
        let mut tc = base_tc(m);
        tc.omp_threads = 12;
        let seq = run(m, tc);
        tc.inter_op = 3;
        let par = run(m, tc);
        assert!(par > 1.05 * seq, "inter_op should help ssd: {seq} vs {par}");
    }

    #[test]
    fn parse_round_trips() {
        for m in ModelId::all() {
            assert_eq!(ModelId::parse(m.name()), Some(m));
            assert_eq!(ModelId::parse(m.short_name()), Some(m));
        }
        assert_eq!(ModelId::parse("nope"), None);
    }

    #[test]
    fn dispatch_mix_matches_design() {
        // ResNet50 hot ops all oneDNN; transformer mixed; NCF mostly Eigen+serial.
        let rn = resnet50();
        let dnn_flops: f64 = rn.iter().filter(|o| o.dispatch == Dispatch::OneDnn).map(|o| o.flops_per_ex).sum();
        let all_flops: f64 = rn.iter().map(|o| o.flops_per_ex).sum();
        assert!(dnn_flops / all_flops > 0.98);

        let tr = transformer_lt();
        let eig_flops: f64 = tr.iter().filter(|o| o.dispatch == Dispatch::Eigen).map(|o| o.flops_per_ex).sum();
        let tr_all: f64 = tr.iter().map(|o| o.flops_per_ex).sum();
        assert!(eig_flops / tr_all > 0.05 && eig_flops / tr_all < 0.5);
    }
}
