//! Operator model for the simulated TensorFlow CPU backend.
//!
//! The real Intel-TF backend dispatches each dataflow-graph operator either
//! to the oneDNN primitives (threaded by the *OpenMP* runtime, i.e.
//! `OMP_NUM_THREADS` / `KMP_BLOCKTIME`) or to the default Eigen kernels
//! (threaded by TF's *intra-op* pool, i.e. `intra_op_parallelism_threads`).
//! That dispatch split is the single most important mechanism behind the
//! paper's observations — e.g. ResNet50-INT8 being completely insensitive
//! to `intra_op` (§4.3) because every hot op is oneDNN — so it is a
//! first-class attribute here.

/// Which thread pool executes an operator.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Dispatch {
    /// oneDNN primitive: parallelised by the OpenMP team
    /// (`OMP_NUM_THREADS` threads, `KMP_BLOCKTIME` spin semantics).
    OneDnn,
    /// Eigen kernel: parallelised by TF's intra-op pool
    /// (`intra_op_parallelism_threads` threads).
    Eigen,
    /// Bookkeeping op that runs single-threaded on the inter-op worker.
    Serial,
}

/// Broad operator class — determines default cost-model coefficients.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum OpKind {
    Conv2d,
    DepthwiseConv,
    MatMul,
    BatchMatMul,
    Embedding,
    Attention,
    Norm,
    Eltwise,
    Pool,
    Softmax,
    Bookkeeping,
}

/// One (possibly aggregated) operator of a model's dataflow graph.
///
/// Models aggregate repeated primitives into stage-level ops; `regions`
/// records how many OpenMP/Eigen parallel regions the stage actually
/// launches, because per-region fork/wake overhead (the KMP_BLOCKTIME
/// mechanism) scales with that count, not with the op count.
#[derive(Debug, Clone)]
pub struct Op {
    pub name: String,
    pub kind: OpKind,
    pub dispatch: Dispatch,
    /// Floating-point (or int8-ops) work per input example.
    pub flops_per_ex: f64,
    /// Memory traffic per example (activations), bytes.
    pub bytes_per_ex: f64,
    /// Batch-independent traffic (weights), bytes.
    pub fixed_bytes: f64,
    /// Parallelisable fraction of the op's work (Amdahl).
    pub parallel_frac: f64,
    /// Number of parallel regions this (aggregated) op launches.
    pub regions: u32,
    /// Graph predecessors (indices into the model's op list).
    pub preds: Vec<usize>,
}

impl Op {
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        name: &str,
        kind: OpKind,
        dispatch: Dispatch,
        flops_per_ex: f64,
        bytes_per_ex: f64,
        fixed_bytes: f64,
        parallel_frac: f64,
        regions: u32,
        preds: Vec<usize>,
    ) -> Op {
        assert!((0.0..=1.0).contains(&parallel_frac), "bad parallel_frac");
        assert!(regions >= 1, "op must launch at least one region");
        Op {
            name: name.to_string(),
            kind,
            dispatch,
            flops_per_ex,
            bytes_per_ex,
            fixed_bytes,
            parallel_frac,
            regions,
            preds,
        }
    }

    /// Total compute work for a batch, in FLOPs.
    pub fn flops(&self, batch: i64) -> f64 {
        self.flops_per_ex * batch as f64
    }

    /// Total memory traffic for a batch, in bytes.
    pub fn bytes(&self, batch: i64) -> f64 {
        self.bytes_per_ex * batch as f64 + self.fixed_bytes
    }
}

/// Numeric precision of a model's weights/activations. INT8 raises the
/// usable compute peak (VNNI) and shrinks memory traffic, which shortens
/// oneDNN regions and makes per-region overheads relatively larger —
/// exactly why KMP_BLOCKTIME matters more for the INT8 model in Fig. 6.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Precision {
    Fp32,
    Int8,
}

impl Precision {
    /// Multiplier on the FP32 compute peak (VNNI int8 dot ≈ 3.3× FP32 FMA
    /// throughput in practice, below the 4× theoretical).
    pub fn peak_multiplier(self) -> f64 {
        match self {
            Precision::Fp32 => 1.0,
            Precision::Int8 => 3.3,
        }
    }

    /// Multiplier on memory traffic (int8 tensors are 4× smaller, but
    /// some f32 stays: bias/scale/requantisation — call it 3×).
    pub fn bytes_multiplier(self) -> f64 {
        match self {
            Precision::Fp32 => 1.0,
            Precision::Int8 => 1.0 / 3.0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn op() -> Op {
        Op::new("c", OpKind::Conv2d, Dispatch::OneDnn, 1e9, 1e6, 5e6, 0.95, 4, vec![])
    }

    #[test]
    fn batch_scaling() {
        let o = op();
        assert_eq!(o.flops(2), 2e9);
        assert_eq!(o.bytes(2), 2e6 + 5e6);
        assert_eq!(o.bytes(0), 5e6);
    }

    #[test]
    fn int8_multipliers() {
        assert!(Precision::Int8.peak_multiplier() > 3.0);
        assert!(Precision::Int8.bytes_multiplier() < 0.5);
        assert_eq!(Precision::Fp32.peak_multiplier(), 1.0);
    }

    #[test]
    #[should_panic]
    fn rejects_bad_parallel_frac() {
        Op::new("x", OpKind::Eltwise, Dispatch::Eigen, 1.0, 1.0, 0.0, 1.5, 1, vec![]);
    }

    #[test]
    #[should_panic]
    fn rejects_zero_regions() {
        Op::new("x", OpKind::Eltwise, Dispatch::Eigen, 1.0, 1.0, 0.0, 0.5, 0, vec![]);
    }
}
