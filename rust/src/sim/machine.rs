//! Hardware model of the paper's target system: a dual-socket 24-core
//! 2nd-gen Intel Xeon Scalable Gold 6252 ("Cascade Lake") at 3.9 GHz with
//! hyper-threading on (§4.1).
//!
//! Every coefficient is a documented, order-of-magnitude-faithful constant.
//! Absolute numbers do not need to match the authors' testbed (our substrate
//! is a simulator); the *relative* behaviour — thread scaling, bandwidth
//! saturation, NUMA, over-subscription, fork/wake costs — is what the
//! tuning landscape is made of.

/// Target-machine description.
#[derive(Debug, Clone)]
pub struct Machine {
    /// Physical cores (2 sockets × 24).
    pub cores: usize,
    /// SMT ways per core (hyper-threading on).
    pub smt: usize,
    /// Cores per socket (NUMA domain size).
    pub socket_cores: usize,
    /// Peak FP32 FLOP/s of one core: 3.9 GHz × 2 AVX-512 FMA ports ×
    /// 16 fp32 lanes × 2 (fma) ≈ 250 GFLOP/s theoretical; we use an
    /// achievable 60% of that for dense kernels.
    pub peak_flops_core: f64,
    /// Aggregate DRAM bandwidth, bytes/s (6 channels DDR4-2933 per socket
    /// ≈ 140 GB/s each; ~75% achievable).
    pub mem_bw: f64,
    /// Threads needed to saturate one socket's bandwidth.
    pub bw_sat_threads: f64,
    /// Last-level cache capacity, bytes (35.75 MiB per socket).
    pub llc_bytes: f64,
    /// Cost to fork/join one parallel region (base), seconds.
    pub fork_base_s: f64,
    /// Additional fork cost per team thread, seconds.
    pub fork_per_thread_s: f64,
    /// Cost to wake a sleeping OpenMP team (futex path), seconds.
    pub wake_s: f64,
    /// Memory-time multiplier when a team spans both sockets.
    pub numa_penalty: f64,
    /// Over-subscription exponent: slowdown = (demand/capacity)^gamma.
    pub oversub_gamma: f64,
    /// Per-op runtime dispatch overhead (TF executor bookkeeping), seconds.
    pub dispatch_s: f64,
}

impl Machine {
    /// The paper's target system (Xeon Gold 6252 ×2, HT on, 3.9 GHz).
    pub fn cascade_lake() -> Machine {
        Machine {
            cores: 48,
            smt: 2,
            socket_cores: 24,
            peak_flops_core: 150e9,
            mem_bw: 210e9,
            bw_sat_threads: 8.0,
            llc_bytes: 2.0 * 35.75e6,
            fork_base_s: 1.5e-6,
            fork_per_thread_s: 0.12e-6,
            wake_s: 9e-6,
            numa_penalty: 1.22,
            oversub_gamma: 1.25,
            dispatch_s: 8e-6,
        }
    }

    /// Hardware thread capacity (cores × SMT).
    pub fn hw_threads(&self) -> usize {
        self.cores * self.smt
    }

    /// Over-subscription slowdown for a total thread demand.
    ///
    /// demand ≤ cores: no penalty. cores < demand ≤ hw_threads: SMT absorbs
    /// some of it (mild penalty). Beyond hw threads: context-switch thrash,
    /// super-linear penalty. Continuous and monotone in demand.
    pub fn oversub_slowdown(&self, demand: f64) -> f64 {
        let c = self.cores as f64;
        let ht = self.hw_threads() as f64;
        if demand <= c {
            1.0
        } else if demand <= ht {
            // SMT region: a hyper-thread shares execution ports with its
            // sibling, so each extra thread costs ~45% of a core's worth.
            1.0 + 0.45 * (demand - c) / c
        } else {
            let smt_pen = 1.0 + 0.45 * (ht - c) / c;
            smt_pen * (demand / ht).powf(self.oversub_gamma)
        }
    }

    /// Memory-bandwidth-bound speedup cap: adding threads beyond
    /// `bw_sat_threads` does not add bandwidth.
    pub fn mem_speedup(&self, threads: f64) -> f64 {
        threads.min(self.bw_sat_threads).max(1.0)
    }

    /// Compute-scaling cap: SMT siblings share FMA ports, so dense-kernel
    /// compute scales only to the physical core count.
    pub fn compute_threads(&self, team: f64) -> f64 {
        team.clamp(1.0, self.cores as f64)
    }

    /// NUMA multiplier for a team of `threads`.
    pub fn numa_mult(&self, threads: f64) -> f64 {
        if threads > self.socket_cores as f64 {
            self.numa_penalty
        } else {
            1.0
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn capacity() {
        let m = Machine::cascade_lake();
        assert_eq!(m.cores, 48);
        assert_eq!(m.hw_threads(), 96);
    }

    #[test]
    fn oversub_monotone_and_continuous() {
        let m = Machine::cascade_lake();
        assert_eq!(m.oversub_slowdown(10.0), 1.0);
        assert_eq!(m.oversub_slowdown(48.0), 1.0);
        let mut prev = 0.0;
        for d in 1..300 {
            let s = m.oversub_slowdown(d as f64);
            assert!(s >= prev - 1e-12, "not monotone at {d}");
            prev = s;
        }
        // continuity at the SMT boundary
        let a = m.oversub_slowdown(95.999);
        let b = m.oversub_slowdown(96.001);
        assert!((a - b).abs() < 1e-3);
    }

    #[test]
    fn smt_region_milder_than_thrash() {
        let m = Machine::cascade_lake();
        let smt = m.oversub_slowdown(96.0) / m.oversub_slowdown(48.0);
        let thrash = m.oversub_slowdown(192.0) / m.oversub_slowdown(96.0);
        assert!(smt < thrash);
    }

    #[test]
    fn mem_speedup_saturates() {
        let m = Machine::cascade_lake();
        assert_eq!(m.mem_speedup(2.0), 2.0);
        assert_eq!(m.mem_speedup(100.0), m.bw_sat_threads);
        assert_eq!(m.mem_speedup(0.5), 1.0);
    }

    #[test]
    fn numa_kicks_in_past_socket() {
        let m = Machine::cascade_lake();
        assert_eq!(m.numa_mult(24.0), 1.0);
        assert!(m.numa_mult(25.0) > 1.0);
    }
}
