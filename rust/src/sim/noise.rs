//! Measurement noise model.
//!
//! Real throughput measurements vary run to run (OS jitter, turbo states,
//! cache state); the paper's Fig. 5 NMS curves are visibly noisy. We apply
//! a multiplicative log-normal factor exp(N(0, sigma)) per evaluation from
//! a seeded stream, so experiments are reproducible yet repeated
//! evaluations of the same configuration differ like real reruns.

use crate::util::Rng;

/// Default relative noise (sigma of log-throughput): ~1.5%.
pub const DEFAULT_SIGMA: f64 = 0.015;

/// Seeded multiplicative noise stream.
#[derive(Debug, Clone)]
pub struct NoiseModel {
    rng: Rng,
    pub sigma: f64,
}

impl NoiseModel {
    pub fn new(seed: u64, sigma: f64) -> NoiseModel {
        assert!(sigma >= 0.0, "noise sigma must be non-negative");
        NoiseModel { rng: Rng::new(seed), sigma }
    }

    /// Noise-free model (for the exhaustive sweep ground truth).
    pub fn none() -> NoiseModel {
        NoiseModel::new(0, 0.0)
    }

    /// Apply one draw of noise to a true throughput.
    pub fn apply(&mut self, value: f64) -> f64 {
        if self.sigma == 0.0 {
            return value;
        }
        value * (self.rng.normal() * self.sigma).exp()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zero_sigma_is_identity() {
        let mut n = NoiseModel::none();
        assert_eq!(n.apply(123.0), 123.0);
    }

    #[test]
    fn noise_is_seeded_and_reproducible() {
        let mut a = NoiseModel::new(7, 0.02);
        let mut b = NoiseModel::new(7, 0.02);
        for _ in 0..50 {
            assert_eq!(a.apply(100.0), b.apply(100.0));
        }
    }

    #[test]
    fn noise_magnitude_sane() {
        let mut n = NoiseModel::new(1, DEFAULT_SIGMA);
        let draws: Vec<f64> = (0..10_000).map(|_| n.apply(100.0)).collect();
        let mean = draws.iter().sum::<f64>() / draws.len() as f64;
        assert!((mean - 100.0).abs() < 1.0, "mean {mean}");
        // ~99.7% of draws within 3 sigma
        let outliers = draws.iter().filter(|&&d| (d / 100.0).ln().abs() > 3.0 * DEFAULT_SIGMA).count();
        assert!(outliers < 100, "outliers {outliers}");
    }

    #[test]
    fn repeated_evals_differ() {
        let mut n = NoiseModel::new(2, DEFAULT_SIGMA);
        assert_ne!(n.apply(100.0), n.apply(100.0));
    }

    #[test]
    #[should_panic]
    fn negative_sigma_rejected() {
        NoiseModel::new(0, -0.1);
    }
}
