//! The `tftune dashboard` engine: folds an event stream (recorded
//! `--events-file` JSONL or the daemon's live `--events-addr` socket)
//! into terminal panels — regret, Pareto hypervolume, throughput, lease
//! churn — and post-processes a recorded stream into critical-path
//! accounting (`--report`): where a session's wall-clock actually went,
//! split into evaluator wait vs surrogate lock vs wire vs acquisition
//! scoring.
//!
//! Everything here is a pure fold over [`EventRecord`]s, so the same
//! code path serves the live dashboard, the offline report, and the
//! event-accounting tests. In particular [`replay_history`] rebuilds a
//! session's `History` from `trial-measured` events alone —
//! bit-identically, because the records carry full configs and
//! shortest-round-trip f64 payloads.

use std::collections::BTreeMap;
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::path::Path;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use super::{Event, EventRecord};
use crate::history::{History, Measurement};

/// The reference-point margin the session uses for its `hypervolume`
/// events (`History::hypervolume_auto`): consumers replaying the stream
/// must use the same value to land on the same bits.
pub const HV_MARGIN: f64 = 0.5;

/// Rebuild the session's `History` from its event stream alone: every
/// `trial-measured` record carries the full config, value, cost and
/// objective vector, in completion order. The result is bit-identical
/// to the live session's history (the accounting suite pins this).
pub fn replay_history(records: &[EventRecord]) -> History {
    let mut h = History::new();
    for r in records {
        if let Event::TrialMeasured { trial, config, value, cost_s, objectives } = &r.event {
            let m = Measurement::new(*value).with_cost_s(*cost_s);
            h.push_trial_multi(*trial, config.clone(), &m, objectives.clone());
        }
    }
    h
}

/// Running fold of an event stream into everything the panels show.
#[derive(Debug, Default)]
pub struct DashboardState {
    /// Trials handed to evaluators / measurements recorded.
    pub issued: u64,
    pub measured: u64,
    /// Monotone best-so-far objective curve (appended per measurement).
    pub best_curve: Vec<f64>,
    /// Hypervolume trace (multi-objective sessions).
    pub hv_curve: Vec<f64>,
    /// Size of the non-dominated front after the last advance.
    pub front_size: usize,
    /// Trial id of the last front advance.
    pub front_trial: u64,
    /// `t_ns` stamps of measurements, for the throughput window.
    measured_at: Vec<u64>,
    /// Lease churn counters.
    pub leases_published: u64,
    pub leases_expired: u64,
    /// Wire catch-up totals.
    pub sync_rows: u64,
    pub sync_bytes: u64,
    /// Surrogate totals.
    pub tells: u64,
    pub drains: u64,
    pub factor_rows: usize,
    pub factor_entries: usize,
    /// Fleet + persistence counters.
    pub spaces_created: u64,
    pub spaces_evicted: u64,
    pub snapshots: u64,
    pub wal_records: usize,
    /// Per-source sequence gaps observed in the stream — each gap is a
    /// record the bus (or a stalled subscriber queue) dropped.
    pub seq_gaps: u64,
    /// Latest timestamp seen (nanos since the emitting bus's epoch).
    pub last_t_ns: u64,
    next_seq: BTreeMap<String, u64>,
}

impl DashboardState {
    pub fn new() -> DashboardState {
        DashboardState::default()
    }

    /// Pre-seed the per-source sequence cursors from an `obs-hello`, so
    /// a subscriber that joins mid-stream doesn't misread the skipped
    /// prefix as drops.
    pub fn seed_seqs(&mut self, seqs: &[(String, u64)]) {
        for (name, next) in seqs {
            self.next_seq.insert(name.clone(), *next);
        }
    }

    /// Fold one record in.
    pub fn apply(&mut self, r: &EventRecord) {
        let cursor = self.next_seq.entry(r.source.clone()).or_insert(r.seq);
        if r.seq > *cursor {
            self.seq_gaps += r.seq - *cursor;
        }
        *cursor = r.seq + 1;
        self.last_t_ns = self.last_t_ns.max(r.t_ns);
        match &r.event {
            Event::TrialIssued { .. } => self.issued += 1,
            Event::TrialMeasured { value, .. } => {
                self.measured += 1;
                self.measured_at.push(r.t_ns);
                let best = self.best_curve.last().copied().unwrap_or(f64::NEG_INFINITY);
                self.best_curve.push(best.max(*value));
            }
            Event::AskStart { .. } | Event::AskEnd { .. } => {}
            Event::SurrogateTell { .. } => self.tells += 1,
            Event::SurrogateDrain { .. } => self.drains += 1,
            Event::FactorSize { rows, entries } => {
                self.factor_rows = *rows;
                self.factor_entries = *entries;
            }
            Event::FrontAdvanced { trial, front_size } => {
                self.front_size = *front_size;
                self.front_trial = *trial;
            }
            Event::Hypervolume { hv } => self.hv_curve.push(*hv),
            Event::SyncFactor { rows, bytes, .. } => {
                self.sync_rows += *rows as u64;
                self.sync_bytes += *bytes as u64;
            }
            Event::LeasePublished { .. } => self.leases_published += 1,
            Event::LeaseExpired { leases } => self.leases_expired += *leases as u64,
            Event::SpaceCreated { .. } => self.spaces_created += 1,
            Event::SpaceEvicted { .. } => self.spaces_evicted += 1,
            Event::SnapshotWritten { .. } => self.snapshots += 1,
            Event::WalSync { records } => self.wal_records = *records,
        }
    }

    /// Measurements completed in the trailing `window` of stream time,
    /// as a rate per second. 0 until two measurements exist.
    pub fn throughput(&self, window: Duration) -> f64 {
        let Some(&last) = self.measured_at.last() else { return 0.0 };
        let w_ns = window.as_nanos() as u64;
        let floor = last.saturating_sub(w_ns);
        let n = self.measured_at.iter().rev().take_while(|&&t| t >= floor).count();
        if n < 2 {
            return 0.0;
        }
        n as f64 / (w_ns as f64 / 1e9).max(1e-9)
    }

    /// Current best objective value, if any measurement landed.
    pub fn best(&self) -> Option<f64> {
        self.best_curve.last().copied()
    }

    /// Render the panels as one ANSI frame (clear-screen prefix when
    /// `live`, plain text otherwise — the latter is what `--once`
    /// prints and what tests assert against).
    pub fn render(&self, live: bool, dropped_hint: u64) -> String {
        let mut s = String::new();
        if live {
            s.push_str("\x1b[2J\x1b[H");
        }
        let t_s = self.last_t_ns as f64 / 1e9;
        s.push_str(&format!("tftune dashboard  ·  t+{t_s:.1}s\n"));
        s.push_str(&format!(
            "trials   issued {:>6}  measured {:>6}  throughput {:>7.2}/s\n",
            self.issued,
            self.measured,
            self.throughput(Duration::from_secs(10)),
        ));
        match self.best() {
            Some(b) => s.push_str(&format!(
                "regret   best {b:<14.6} {}\n",
                sparkline(&self.best_curve, 48)
            )),
            None => s.push_str("regret   (no measurements yet)\n"),
        }
        if let Some(&hv) = self.hv_curve.last() {
            s.push_str(&format!(
                "pareto   hv {hv:<16.6} front {:>4} (last advance @ trial {})\n         {}\n",
                self.front_size,
                self.front_trial,
                sparkline(&self.hv_curve, 48)
            ));
        } else if self.front_size > 0 {
            s.push_str(&format!(
                "front    size {:>4} (last advance @ trial {})\n",
                self.front_size, self.front_trial
            ));
        }
        s.push_str(&format!(
            "engine   tells {:>7}  drains {:>6}  factor {} rows / {} entries\n",
            self.tells, self.drains, self.factor_rows, self.factor_entries
        ));
        s.push_str(&format!(
            "wire     sync {:>6} rows / {} bytes   leases +{} / -{}\n",
            self.sync_rows, self.sync_bytes, self.leases_published, self.leases_expired
        ));
        if self.spaces_created + self.spaces_evicted + self.snapshots > 0
            || self.wal_records > 0
        {
            s.push_str(&format!(
                "fleet    spaces +{} / -{}   snapshots {}   wal {} records\n",
                self.spaces_created, self.spaces_evicted, self.snapshots, self.wal_records
            ));
        }
        s.push_str(&format!(
            "stream   seq gaps {}  publisher dropped {}\n",
            self.seq_gaps, dropped_hint
        ));
        s
    }
}

/// A unicode sparkline of `values`, downsampled to at most `width`.
fn sparkline(values: &[f64], width: usize) -> String {
    const BARS: [char; 8] = ['▁', '▂', '▃', '▄', '▅', '▆', '▇', '█'];
    if values.is_empty() || width == 0 {
        return String::new();
    }
    let take_every = values.len().div_ceil(width);
    let pts: Vec<f64> = values
        .iter()
        .copied()
        .enumerate()
        .filter(|(i, _)| i % take_every == 0 || *i == values.len() - 1)
        .map(|(_, v)| v)
        .collect();
    let (lo, hi) = pts
        .iter()
        .fold((f64::INFINITY, f64::NEG_INFINITY), |(l, h), &v| (l.min(v), h.max(v)));
    let span = (hi - lo).max(1e-300);
    pts.iter()
        .map(|&v| BARS[(((v - lo) / span) * 7.0).round().clamp(0.0, 7.0) as usize])
        .collect()
}

/// Wall-clock split of a recorded session (`dashboard --report`): the
/// four critical-path categories the ISSUE names, plus the residue.
/// All seconds. Categories are *attributed* time: the evaluator column
/// sums measurement costs (which overlap wall-clock under a parallel
/// session — the report prints the parallelism ratio rather than
/// pretending the columns partition the wall).
#[derive(Debug, Clone, PartialEq)]
pub struct CriticalPath {
    /// End-to-end stream time: max − min `t_ns` over the records.
    pub wall_s: f64,
    /// Σ `cost_s` over `trial-measured` — time spent inside evaluators.
    pub evaluator_wait_s: f64,
    /// Σ `wait_ns` over `surrogate-drain` — lock acquisition + queue
    /// drains on the shared factor.
    pub surrogate_lock_s: f64,
    /// Σ `ns` over `sync-factor` — catch-up round trips on the wire.
    pub wire_s: f64,
    /// Σ `ns` over `ask-end`, minus lock and wire time nested inside
    /// the asks (clamped at 0) — pure acquisition scoring.
    pub acquisition_s: f64,
    /// Wall minus everything attributable (clamped at 0): scheduling,
    /// serialisation, the session loop itself.
    pub other_s: f64,
    pub trials: u64,
    /// Per-source sequence gaps in the record (dropped events).
    pub seq_gaps: u64,
}

impl CriticalPath {
    /// The report as printable text, one category per line with its
    /// share of the wall-clock.
    pub fn render(&self) -> String {
        let wall = self.wall_s.max(1e-12);
        let pct = |v: f64| 100.0 * v / wall;
        let mut s = String::new();
        s.push_str(&format!(
            "critical path · {} trials over {:.3}s wall\n",
            self.trials, self.wall_s
        ));
        s.push_str(&format!(
            "  evaluator wait      {:>10.3}s  {:>5.1}%\n",
            self.evaluator_wait_s,
            pct(self.evaluator_wait_s)
        ));
        s.push_str(&format!(
            "  surrogate lock      {:>10.3}s  {:>5.1}%\n",
            self.surrogate_lock_s,
            pct(self.surrogate_lock_s)
        ));
        s.push_str(&format!(
            "  wire (sync-factor)  {:>10.3}s  {:>5.1}%\n",
            self.wire_s,
            pct(self.wire_s)
        ));
        s.push_str(&format!(
            "  acquisition scoring {:>10.3}s  {:>5.1}%\n",
            self.acquisition_s,
            pct(self.acquisition_s)
        ));
        s.push_str(&format!(
            "  other               {:>10.3}s  {:>5.1}%\n",
            self.other_s,
            pct(self.other_s)
        ));
        if self.evaluator_wait_s > self.wall_s {
            s.push_str(&format!(
                "  (evaluator time exceeds wall ×{:.2}: parallel session)\n",
                self.evaluator_wait_s / wall
            ));
        }
        if self.seq_gaps > 0 {
            s.push_str(&format!(
                "  warning: {} dropped event(s) — times are lower bounds\n",
                self.seq_gaps
            ));
        }
        s
    }
}

/// Post-process a recorded stream into its [`CriticalPath`] accounting.
pub fn critical_path(records: &[EventRecord]) -> CriticalPath {
    let mut min_t = u64::MAX;
    let mut max_t = 0u64;
    let mut evaluator_ns = 0.0f64;
    let mut lock_ns = 0u64;
    let mut wire_ns = 0u64;
    let mut ask_ns = 0u64;
    let mut trials = 0u64;
    let mut state = DashboardState::new();
    for r in records {
        state.apply(r);
        min_t = min_t.min(r.t_ns);
        max_t = max_t.max(r.t_ns);
        match &r.event {
            Event::TrialMeasured { cost_s, .. } => {
                trials += 1;
                evaluator_ns += cost_s * 1e9;
            }
            Event::SurrogateDrain { wait_ns, .. } => lock_ns += wait_ns,
            Event::SyncFactor { ns, .. } => wire_ns += ns,
            Event::AskEnd { ns, .. } => ask_ns += ns,
            _ => {}
        }
    }
    let wall_s = if max_t > min_t { (max_t - min_t) as f64 / 1e9 } else { 0.0 };
    let evaluator_wait_s = evaluator_ns / 1e9;
    let surrogate_lock_s = lock_ns as f64 / 1e9;
    let wire_s = wire_ns as f64 / 1e9;
    // Drains and syncs run nested inside asks (the engine locks, and a
    // replica catches up, on the ask path), so subtract them out of the
    // ask total to leave pure scoring.
    let acquisition_s = (ask_ns as f64 / 1e9 - surrogate_lock_s - wire_s).max(0.0);
    let attributed = evaluator_wait_s + surrogate_lock_s + wire_s + acquisition_s;
    CriticalPath {
        wall_s,
        evaluator_wait_s,
        surrogate_lock_s,
        wire_s,
        acquisition_s,
        other_s: (wall_s - attributed).max(0.0),
        trials,
        seq_gaps: state.seq_gaps,
    }
}

/// Options for the live `dashboard` loops.
#[derive(Debug, Clone)]
pub struct DashOptions {
    /// Frame interval.
    pub refresh_ms: u64,
    /// Render a single plain frame (no ANSI clear) and exit.
    pub once: bool,
    /// Stop after this much wall-clock (None = until EOF/disconnect,
    /// or forever for a growing file).
    pub max_seconds: Option<f64>,
}

impl Default for DashOptions {
    fn default() -> DashOptions {
        DashOptions { refresh_ms: 500, once: false, max_seconds: None }
    }
}

fn deadline(opts: &DashOptions) -> Option<Instant> {
    opts.max_seconds.map(|s| Instant::now() + Duration::from_secs_f64(s.max(0.0)))
}

/// Tail a recorded (possibly still-growing) events file into live
/// panels on `out`. With `once`, folds what's there and prints one
/// frame. Undecodable lines (e.g. a partial line at the write frontier)
/// are skipped, not fatal — the next poll rereads from the same offset.
pub fn follow_file(path: &Path, opts: &DashOptions, out: &mut dyn Write) -> Result<()> {
    let mut state = DashboardState::new();
    let mut offset = 0u64;
    let stop_at = deadline(opts);
    loop {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading events file {}", path.display()))?;
        let tail = &text[offset.min(text.len() as u64) as usize..];
        let mut consumed = 0usize;
        for line in tail.split_inclusive('\n') {
            if !line.ends_with('\n') {
                break; // partial frontier line: retry next poll
            }
            consumed += line.len();
            let trimmed = line.trim_end();
            if trimmed.is_empty() {
                continue;
            }
            if let Ok(rec) = super::decode_event_record(trimmed) {
                state.apply(&rec);
            }
        }
        offset += consumed as u64;
        write!(out, "{}", state.render(!opts.once, 0))?;
        out.flush().ok();
        if opts.once {
            return Ok(());
        }
        if stop_at.is_some_and(|d| Instant::now() >= d) {
            return Ok(());
        }
        std::thread::sleep(Duration::from_millis(opts.refresh_ms.max(10)));
    }
}

/// Subscribe to a live `--events-addr` publisher and render until
/// disconnect (or `max_seconds`/`once`). Returns the folded state so
/// callers (and tests) can inspect what was seen.
pub fn follow_socket(
    addr: &str,
    opts: &DashOptions,
    out: &mut dyn Write,
) -> Result<DashboardState> {
    let stream = TcpStream::connect(addr).with_context(|| format!("connecting {addr}"))?;
    stream.set_nodelay(true).ok();
    let mut w = stream.try_clone()?;
    writeln!(w, "{}", crate::server::proto::encode_obs_subscribe())?;
    let mut reader = BufReader::new(stream);
    let mut hello_line = String::new();
    reader.read_line(&mut hello_line)?;
    let (dropped, seqs) = crate::server::proto::decode_obs_hello(hello_line.trim_end())
        .map_err(|e| anyhow::anyhow!("bad obs-hello: {e}"))?;
    let mut state = DashboardState::new();
    state.seed_seqs(&seqs);
    let stop_at = deadline(opts);
    reader
        .get_ref()
        .set_read_timeout(Some(Duration::from_millis(opts.refresh_ms.max(10))))?;
    let mut line = String::new();
    loop {
        line.clear();
        match reader.read_line(&mut line) {
            Ok(0) => break, // publisher closed
            Ok(_) => {
                if let Ok(rec) = super::decode_event_record(line.trim_end()) {
                    state.apply(&rec);
                }
            }
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut => {}
            Err(e) => return Err(e.into()),
        }
        write!(out, "{}", state.render(!opts.once, dropped))?;
        out.flush().ok();
        if opts.once {
            break;
        }
        if stop_at.is_some_and(|d| Instant::now() >= d) {
            break;
        }
    }
    Ok(state)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::Event;

    fn rec(source: &str, seq: u64, t_ns: u64, event: Event) -> EventRecord {
        EventRecord { source: source.into(), seq, t_ns, event }
    }

    fn measured(trial: u64, value: f64, cost_s: f64) -> Event {
        Event::TrialMeasured {
            trial,
            config: vec![1, 2, 3],
            value,
            cost_s,
            objectives: vec![],
        }
    }

    #[test]
    fn state_folds_counts_and_curves() {
        let mut s = DashboardState::new();
        s.apply(&rec("session", 0, 10, Event::TrialIssued { trial: 0 }));
        s.apply(&rec("session", 1, 20, measured(0, 3.0, 0.5)));
        s.apply(&rec("session", 2, 30, Event::TrialIssued { trial: 1 }));
        s.apply(&rec("session", 3, 40, measured(1, 1.0, 0.25)));
        s.apply(&rec("session", 4, 50, Event::Hypervolume { hv: 2.5 }));
        assert_eq!(s.issued, 2);
        assert_eq!(s.measured, 2);
        assert_eq!(s.best_curve, vec![3.0, 3.0]);
        assert_eq!(s.hv_curve, vec![2.5]);
        assert_eq!(s.seq_gaps, 0);
        let frame = s.render(false, 0);
        assert!(frame.contains("measured"), "{frame}");
        assert!(!frame.contains('\u{1b}'), "--once frames must be ANSI-free");
        assert!(s.render(true, 0).contains('\u{1b}'));
    }

    #[test]
    fn seq_gaps_count_drops_and_hello_seeding_suppresses_false_gaps() {
        let mut s = DashboardState::new();
        s.apply(&rec("a", 0, 0, Event::SurrogateTell { pending: 1 }));
        s.apply(&rec("a", 3, 1, Event::SurrogateTell { pending: 1 })); // 2 dropped
        assert_eq!(s.seq_gaps, 2);
        // A mid-stream joiner seeded from the hello sees no false gap.
        let mut late = DashboardState::new();
        late.seed_seqs(&[("a".to_string(), 7)]);
        late.apply(&rec("a", 7, 2, Event::SurrogateTell { pending: 1 }));
        assert_eq!(late.seq_gaps, 0);
        // An unseeded mid-stream joiner starts its cursor at first-seen.
        let mut cold = DashboardState::new();
        cold.apply(&rec("a", 7, 2, Event::SurrogateTell { pending: 1 }));
        assert_eq!(cold.seq_gaps, 0);
    }

    #[test]
    fn replay_reconstructs_history_bitwise() {
        let records = vec![
            rec(
                "session",
                0,
                5,
                Event::TrialMeasured {
                    trial: 2,
                    config: vec![4, 16, 128, 0, 10],
                    value: 0.1 + 0.2,
                    cost_s: 1.25,
                    objectives: vec![0.1 + 0.2, -3.5],
                },
            ),
            rec(
                "session",
                1,
                9,
                Event::TrialMeasured {
                    trial: 0,
                    config: vec![1, 1, 64, 0, 1],
                    value: 7.0,
                    cost_s: 0.5,
                    objectives: vec![7.0, -1.0],
                },
            ),
        ];
        let h = replay_history(&records);
        assert_eq!(h.len(), 2);
        let e = h.iter().next().unwrap();
        assert_eq!(e.trial_id, 2);
        assert_eq!(e.value.to_bits(), (0.1f64 + 0.2).to_bits());
        assert_eq!(h.iter().nth(1).unwrap().trial_id, 0);
        // And through the wire codec: encode → decode → replay is still
        // bit-identical (shortest-round-trip f64 text).
        let redecoded: Vec<EventRecord> = records
            .iter()
            .map(|r| super::super::decode_event_record(&super::super::encode_event_record(r)).unwrap())
            .collect();
        let h2 = replay_history(&redecoded);
        let bits = |h: &History| -> Vec<(u64, u64, Vec<u64>)> {
            h.iter()
                .map(|e| {
                    (
                        e.trial_id,
                        e.value.to_bits(),
                        e.objectives.iter().map(|v| v.to_bits()).collect(),
                    )
                })
                .collect()
        };
        assert_eq!(bits(&h), bits(&h2));
    }

    #[test]
    fn critical_path_attributes_and_clamps() {
        let records = vec![
            rec("session", 0, 0, Event::AskStart { want: 1 }),
            rec("session", 1, 1_000_000_000, Event::AskEnd { issued: 1, ns: 1_000_000_000 }),
            rec("surrogate", 0, 500_000_000, Event::SurrogateDrain {
                drained: 1,
                total: 1,
                wait_ns: 200_000_000,
            }),
            rec("replica", 0, 700_000_000, Event::SyncFactor {
                rows: 1,
                bytes: 100,
                ns: 300_000_000,
            }),
            rec("session", 2, 3_000_000_000, measured(0, 1.0, 1.5)),
        ];
        let cp = critical_path(&records);
        assert!((cp.wall_s - 3.0).abs() < 1e-9);
        assert!((cp.evaluator_wait_s - 1.5).abs() < 1e-9);
        assert!((cp.surrogate_lock_s - 0.2).abs() < 1e-9);
        assert!((cp.wire_s - 0.3).abs() < 1e-9);
        // ask 1.0s minus nested 0.2 + 0.3 → 0.5 of pure scoring.
        assert!((cp.acquisition_s - 0.5).abs() < 1e-9);
        // wall 3.0 − attributed 2.5 → 0.5 other.
        assert!((cp.other_s - 0.5).abs() < 1e-9);
        assert_eq!(cp.trials, 1);
        let text = cp.render();
        assert!(text.contains("evaluator wait"), "{text}");
        // Degenerate: nested time exceeding ask time clamps at zero.
        let cp2 = critical_path(&[
            rec("session", 0, 0, Event::AskEnd { issued: 1, ns: 10 }),
            rec("surrogate", 0, 1, Event::SurrogateDrain { drained: 1, total: 1, wait_ns: 50 }),
        ]);
        assert_eq!(cp2.acquisition_s, 0.0);
    }

    #[test]
    fn throughput_windows_recent_measurements() {
        let mut s = DashboardState::new();
        for i in 0..5u64 {
            s.apply(&rec("session", i, i * 1_000_000_000, measured(i, 1.0, 0.1)));
        }
        // 5 measurements inside a 10s window ending at t=4s → 0.5/s.
        let tp = s.throughput(Duration::from_secs(10));
        assert!((tp - 0.5).abs() < 1e-9, "tp {tp}");
        assert_eq!(s.throughput(Duration::from_nanos(1)), 0.0);
    }

    #[test]
    fn sparkline_shapes() {
        assert_eq!(sparkline(&[], 10), "");
        let flat = sparkline(&[1.0, 1.0, 1.0], 10);
        assert_eq!(flat.chars().count(), 3);
        let ramp = sparkline(&(0..100).map(|i| i as f64).collect::<Vec<_>>(), 8);
        assert!(ramp.chars().count() <= 9);
        assert!(ramp.starts_with('▁'));
        assert!(ramp.ends_with('█'));
    }

    #[test]
    fn follow_file_once_renders_a_frame() {
        let dir = std::env::temp_dir().join("tftune_obs_dash_once");
        std::fs::remove_dir_all(&dir).ok();
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("ev.jsonl");
        let lines: Vec<String> = [
            rec("session", 0, 10, Event::TrialIssued { trial: 0 }),
            rec("session", 1, 20, measured(0, 2.0, 0.1)),
        ]
        .iter()
        .map(super::super::encode_event_record)
        .collect();
        std::fs::write(&path, lines.join("\n") + "\n").unwrap();
        let mut out = Vec::new();
        follow_file(&path, &DashOptions { once: true, ..DashOptions::default() }, &mut out)
            .unwrap();
        let text = String::from_utf8(out).unwrap();
        assert!(text.contains("issued"), "{text}");
        assert!(text.contains("best"), "{text}");
        std::fs::remove_dir_all(&dir).ok();
    }
}
