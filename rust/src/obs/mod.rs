//! The live observability plane: a structured, low-overhead event stream
//! threaded through the whole stack (ROADMAP "Live observability plane";
//! the snailtrail lineage — typed per-event records over TCP to an online
//! dashboard).
//!
//! Every layer that does interesting work emits typed [`Event`]s through
//! an [`EventSource`] handle: the session (trial issued/measured, batch
//! ask start/end, front advanced, hypervolume), the shared surrogate
//! (tell enqueue, drain, factor size), the remote replica (sync-factor
//! bytes, lease publication), the fleet daemon (space create/evict,
//! lease churn, served sync bytes) and the persistence plane (snapshot,
//! WAL sync). Each record carries the source name, a **monotonic
//! per-source sequence number** and a **relative-nanos timestamp**
//! (nanoseconds since the bus was created), so a consumer can detect
//! drops per source and reconstruct timelines without wall-clock skew.
//!
//! # Backpressure and drop semantics
//!
//! The hot paths this plane observes (`SharedSurrogate::tell`, the BO
//! ask loop) must never block on an observer, so the [`EventBus`] is a
//! **bounded, non-blocking MPSC**:
//!
//! - With no sink attached the bus is *disabled*: [`EventSource::emit`]
//!   is a single relaxed atomic load and returns — near-zero, pinned by
//!   the `event_emit_disabled` bench row.
//! - With sinks attached, `emit` allocates the record, stamps seq +
//!   timestamp and `try_send`s it into a bounded channel. A full channel
//!   **drops the record and increments the visible
//!   [`EventBus::dropped`] counter** — it never blocks the emitter. The
//!   consumed sequence number is *not* reused, so a per-source seq gap
//!   in the stream is the drop made visible.
//! - A dedicated collector thread drains the channel, encodes each
//!   record to JSONL once, and fans it out to every sink. Sinks are
//!   trusted to be fast or internally non-blocking: the bundled
//!   [`FileSink`] writes to a local file; the TCP [`EventPublisher`]
//!   gives every subscriber its own bounded queue + writer thread and
//!   *drops* (counting into the same `dropped` counter) when a stalled
//!   subscriber's queue fills. A dead subscriber detaches; it never
//!   stalls the collector, let alone a tell.
//!
//! # Wire framing
//!
//! Events cross the wire (and land in `--events-file`) as JSON lines:
//! `{"src":"session","seq":3,"t_ns":81234,"ev":"trial-measured",...}`.
//! The TCP publisher (`surrogate-serve --events-addr`) speaks a minimal
//! line protocol: the subscriber sends one `{"type":"subscribe"}` line,
//! the publisher answers with an `obs-hello` line carrying the current
//! per-source next-sequence map and the cumulative drop counter (so a
//! reconnecting subscriber knows where the stream resumes), then streams
//! event lines until either side disconnects. Malformed, oversized or
//! hostile subscribe lines are answered with one `error` line and a
//! close — strictly per-connection, like the surrogate protocol
//! (`server/proto.rs`, which owns the subscribe/hello codecs).
//!
//! `tftune dashboard` tails either framing (socket or file) into live
//! regret / Pareto-hypervolume / throughput / lease-churn panels, and
//! `tftune dashboard --report` post-processes an events file into
//! critical-path accounting ([`dashboard`]).

pub mod dashboard;

use std::collections::BTreeMap;
use std::io::Write;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{Receiver, SyncSender, TrySendError};
use std::sync::{mpsc, Arc, Mutex};
use std::time::Instant;

use anyhow::{Context, Result};

use crate::util::json::{parse, Json};

/// Default bound of the bus channel: deep enough that a healthy collector
/// never backpressures a burst, small enough that a wedged one costs KBs.
pub const DEFAULT_BUS_CAPACITY: usize = 8192;

/// Default bound of each TCP subscriber's private queue.
pub const DEFAULT_SUBSCRIBER_QUEUE: usize = 1024;

/// One structured event. Field payloads are deliberately plain (ids,
/// counts, f64 bits) so records replay deterministically: the
/// `trial-measured` payload alone reconstructs the session's `History`
/// bit-identically (`obs::dashboard::replay_history`).
#[derive(Debug, Clone, PartialEq)]
pub enum Event {
    /// The session handed a trial to an evaluator.
    TrialIssued { trial: u64 },
    /// A measurement landed and was recorded in `History` — carries
    /// everything `History::push_trial_multi` needs for bitwise replay.
    TrialMeasured { trial: u64, config: Vec<i64>, value: f64, cost_s: f64, objectives: Vec<f64> },
    /// The session asked the engine for a batch (acquisition begins).
    AskStart { want: usize },
    /// The batch came back: `issued` trials after `ns` of engine time.
    AskEnd { issued: usize, ns: u64 },
    /// One observation enqueued on a shared surrogate (`pending` = queue
    /// depth after the push).
    SurrogateTell { pending: usize },
    /// A guard acquisition drained the queue: `drained` new rows folded
    /// in, `total` rows in the store, after `wait_ns` of lock + drain.
    SurrogateDrain { drained: usize, total: usize, wait_ns: u64 },
    /// Factor geometry after a drain: `rows` in the store, `entries`
    /// packed triangle values currently factored.
    FactorSize { rows: usize, entries: usize },
    /// The non-dominated front (or the single-objective incumbent)
    /// advanced at `trial`; the front now holds `front_size` points.
    FrontAdvanced { trial: u64, front_size: usize },
    /// Dominated hypervolume of the current front (multi-objective
    /// sessions; emitted together with `FrontAdvanced`).
    Hypervolume { hv: f64 },
    /// One catch-up `sync-factor` completed: `rows` imported, `bytes`
    /// crossed the wire, `ns` spent in the round trip(s).
    SyncFactor { rows: usize, bytes: usize, ns: u64 },
    /// A lease (in-flight constant-liar point set) was published.
    LeasePublished { id: u64, points: usize },
    /// `leases` leases expired (guard retract, or connection close).
    LeaseExpired { leases: usize },
    /// The fleet daemon created (or restored) a space.
    SpaceCreated { fingerprint: u64, dim: usize },
    /// The fleet daemon evicted an idle space holding `rows` rows.
    SpaceEvicted { fingerprint: u64, rows: usize },
    /// The persistence plane wrote snapshot `seq`.
    SnapshotWritten { seq: usize },
    /// The WAL fsync'd; `records` appended to the log so far.
    WalSync { records: usize },
}

impl Event {
    /// The wire name of this event kind (the `"ev"` field).
    pub fn kind(&self) -> &'static str {
        match self {
            Event::TrialIssued { .. } => "trial-issued",
            Event::TrialMeasured { .. } => "trial-measured",
            Event::AskStart { .. } => "ask-start",
            Event::AskEnd { .. } => "ask-end",
            Event::SurrogateTell { .. } => "surrogate-tell",
            Event::SurrogateDrain { .. } => "surrogate-drain",
            Event::FactorSize { .. } => "factor-size",
            Event::FrontAdvanced { .. } => "front-advanced",
            Event::Hypervolume { .. } => "hypervolume",
            Event::SyncFactor { .. } => "sync-factor",
            Event::LeasePublished { .. } => "lease-published",
            Event::LeaseExpired { .. } => "lease-expired",
            Event::SpaceCreated { .. } => "space-created",
            Event::SpaceEvicted { .. } => "space-evicted",
            Event::SnapshotWritten { .. } => "snapshot-written",
            Event::WalSync { .. } => "wal-sync",
        }
    }
}

/// One stamped record: which source, its monotonic per-source sequence
/// number, nanoseconds since the bus epoch, and the event itself.
#[derive(Debug, Clone, PartialEq)]
pub struct EventRecord {
    pub source: String,
    pub seq: u64,
    pub t_ns: u64,
    pub event: Event,
}

/// Encode one record as a single JSON line (no trailing newline).
/// f64 payloads use the same shortest-round-trip formatting as the rest
/// of the stack, so a decode of this line is bit-exact.
pub fn encode_event_record(r: &EventRecord) -> String {
    let mut pairs: Vec<(&str, Json)> = vec![
        ("src", r.source.as_str().into()),
        ("seq", Json::Num(r.seq as f64)),
        ("t_ns", Json::Num(r.t_ns as f64)),
        ("ev", r.event.kind().into()),
    ];
    match &r.event {
        Event::TrialIssued { trial } => pairs.push(("trial", Json::Num(*trial as f64))),
        Event::TrialMeasured { trial, config, value, cost_s, objectives } => {
            pairs.push(("trial", Json::Num(*trial as f64)));
            pairs.push((
                "config",
                Json::Arr(config.iter().map(|&v| Json::Num(v as f64)).collect()),
            ));
            pairs.push(("value", Json::Num(*value)));
            pairs.push(("cost_s", Json::Num(*cost_s)));
            pairs.push(("objectives", Json::from_f64s(objectives)));
        }
        Event::AskStart { want } => pairs.push(("want", (*want).into())),
        Event::AskEnd { issued, ns } => {
            pairs.push(("issued", (*issued).into()));
            pairs.push(("ns", Json::Num(*ns as f64)));
        }
        Event::SurrogateTell { pending } => pairs.push(("pending", (*pending).into())),
        Event::SurrogateDrain { drained, total, wait_ns } => {
            pairs.push(("drained", (*drained).into()));
            pairs.push(("total", (*total).into()));
            pairs.push(("wait_ns", Json::Num(*wait_ns as f64)));
        }
        Event::FactorSize { rows, entries } => {
            pairs.push(("rows", (*rows).into()));
            pairs.push(("entries", (*entries).into()));
        }
        Event::FrontAdvanced { trial, front_size } => {
            pairs.push(("trial", Json::Num(*trial as f64)));
            pairs.push(("front_size", (*front_size).into()));
        }
        Event::Hypervolume { hv } => pairs.push(("hv", Json::Num(*hv))),
        Event::SyncFactor { rows, bytes, ns } => {
            pairs.push(("rows", (*rows).into()));
            pairs.push(("bytes", (*bytes).into()));
            pairs.push(("ns", Json::Num(*ns as f64)));
        }
        Event::LeasePublished { id, points } => {
            pairs.push(("id", Json::Num(*id as f64)));
            pairs.push(("points", (*points).into()));
        }
        Event::LeaseExpired { leases } => pairs.push(("leases", (*leases).into())),
        Event::SpaceCreated { fingerprint, dim } => {
            pairs.push(("space", format!("{fingerprint:016x}").into()));
            pairs.push(("dim", (*dim).into()));
        }
        Event::SpaceEvicted { fingerprint, rows } => {
            pairs.push(("space", format!("{fingerprint:016x}").into()));
            pairs.push(("rows", (*rows).into()));
        }
        Event::SnapshotWritten { seq } => pairs.push(("snapshot_seq", (*seq).into())),
        Event::WalSync { records } => pairs.push(("records", (*records).into())),
    }
    Json::obj(pairs).to_string()
}

/// Decode one event line. Unknown `"ev"` kinds are an error (the plane
/// is versioned with the crate; a consumer must not silently misread).
pub fn decode_event_record(line: &str) -> Result<EventRecord, String> {
    let j = parse(line).map_err(|e| e.to_string())?;
    let source = j
        .get("src")
        .and_then(Json::as_str)
        .ok_or("missing 'src'")?
        .to_string();
    let seq = j.get("seq").and_then(Json::as_f64).ok_or("missing 'seq'")? as u64;
    let t_ns = j.get("t_ns").and_then(Json::as_f64).ok_or("missing 't_ns'")? as u64;
    let kind = j.get("ev").and_then(Json::as_str).ok_or("missing 'ev'")?;
    let f = |k: &str| -> Result<f64, String> {
        j.get(k).and_then(Json::as_f64).ok_or_else(|| format!("missing '{k}'"))
    };
    let u = |k: &str| -> Result<usize, String> { f(k).map(|v| v as usize) };
    let event = match kind {
        "trial-issued" => Event::TrialIssued { trial: f("trial")? as u64 },
        "trial-measured" => {
            let config = j
                .get("config")
                .and_then(Json::as_arr)
                .ok_or("missing 'config'")?
                .iter()
                .map(|v| v.as_i64().ok_or("non-integer config value".to_string()))
                .collect::<Result<Vec<i64>, String>>()?;
            let objectives = j
                .get("objectives")
                .and_then(Json::as_arr)
                .ok_or("missing 'objectives'")?
                .iter()
                .map(|v| v.as_f64().ok_or("non-numeric objective".to_string()))
                .collect::<Result<Vec<f64>, String>>()?;
            Event::TrialMeasured {
                trial: f("trial")? as u64,
                config,
                value: f("value")?,
                cost_s: f("cost_s")?,
                objectives,
            }
        }
        "ask-start" => Event::AskStart { want: u("want")? },
        "ask-end" => Event::AskEnd { issued: u("issued")?, ns: f("ns")? as u64 },
        "surrogate-tell" => Event::SurrogateTell { pending: u("pending")? },
        "surrogate-drain" => Event::SurrogateDrain {
            drained: u("drained")?,
            total: u("total")?,
            wait_ns: f("wait_ns")? as u64,
        },
        "factor-size" => Event::FactorSize { rows: u("rows")?, entries: u("entries")? },
        "front-advanced" => {
            Event::FrontAdvanced { trial: f("trial")? as u64, front_size: u("front_size")? }
        }
        "hypervolume" => Event::Hypervolume { hv: f("hv")? },
        "sync-factor" => {
            Event::SyncFactor { rows: u("rows")?, bytes: u("bytes")?, ns: f("ns")? as u64 }
        }
        "lease-published" => {
            Event::LeasePublished { id: f("id")? as u64, points: u("points")? }
        }
        "lease-expired" => Event::LeaseExpired { leases: u("leases")? },
        "space-created" => Event::SpaceCreated {
            fingerprint: decode_fingerprint(&j)?,
            dim: u("dim")?,
        },
        "space-evicted" => Event::SpaceEvicted {
            fingerprint: decode_fingerprint(&j)?,
            rows: u("rows")?,
        },
        "snapshot-written" => Event::SnapshotWritten { seq: u("snapshot_seq")? },
        "wal-sync" => Event::WalSync { records: u("records")? },
        other => return Err(format!("unknown event kind '{other}'")),
    };
    Ok(EventRecord { source, seq, t_ns, event })
}

fn decode_fingerprint(j: &Json) -> Result<u64, String> {
    let hex = j.get("space").and_then(Json::as_str).ok_or("missing 'space'")?;
    if hex.len() != 16 {
        return Err(format!("fingerprint '{hex}' is not 16 hex digits"));
    }
    u64::from_str_radix(hex, 16).map_err(|_| format!("fingerprint '{hex}' is not hex"))
}

/// Where encoded records go. Implementations must be fast or internally
/// non-blocking: they run on the bus's single collector thread, and a
/// sink that stalls starves every other sink (though never an emitter —
/// the bounded channel drops instead).
pub trait EventSink: Send {
    /// Handle one record; `line` is its JSONL encoding without the
    /// newline. Return `false` to detach this sink permanently.
    fn publish(&mut self, record: &EventRecord, line: &str) -> bool;
    /// Flush buffered output (called by [`EventBus::flush`]).
    fn flush(&mut self) {}
}

/// Counters shared between emitters, the collector and the publisher:
/// split from the bus body so the collector thread can observe them
/// without keeping the channel sender (and therefore itself) alive.
struct BusCtl {
    /// True while at least one sink is attached.
    enabled: AtomicBool,
    /// Records dropped anywhere in the plane (full bus channel, or a
    /// full subscriber queue) instead of blocking a hot path.
    dropped: AtomicU64,
}

enum BusMsg {
    Event(EventRecord),
    Sink(Box<dyn EventSink>),
    Flush(SyncSender<()>),
}

struct BusShared {
    ctl: Arc<BusCtl>,
    epoch: Instant,
    tx: SyncSender<BusMsg>,
    /// Source registry: name → its live sequence counter. `source()`
    /// returns the *same* counter for a repeated name, so two handles to
    /// one logical source still produce a gap-free sequence.
    sources: Mutex<Vec<(Arc<str>, Arc<AtomicU64>)>>,
}

/// The bounded, non-blocking event bus (module docs). Cheap to clone;
/// all clones share the channel, the sinks and the counters. The
/// collector thread exits when the last clone (and every
/// [`EventSource`]) drops.
#[derive(Clone)]
pub struct EventBus {
    shared: Arc<BusShared>,
}

impl std::fmt::Debug for EventBus {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("EventBus").field("dropped", &self.dropped()).finish()
    }
}

impl EventBus {
    /// A bus with the default channel bound.
    pub fn new() -> EventBus {
        EventBus::with_capacity(DEFAULT_BUS_CAPACITY)
    }

    /// A bus whose channel holds at most `capacity` undelivered records;
    /// the excess is dropped (counted), never blocked on.
    pub fn with_capacity(capacity: usize) -> EventBus {
        let (tx, rx) = mpsc::sync_channel(capacity.max(1));
        let ctl = Arc::new(BusCtl {
            enabled: AtomicBool::new(false),
            dropped: AtomicU64::new(0),
        });
        let collector_ctl = Arc::clone(&ctl);
        std::thread::Builder::new()
            .name("obs-collector".into())
            .spawn(move || collect(rx, collector_ctl))
            .expect("spawning the event-bus collector");
        EventBus {
            shared: Arc::new(BusShared {
                ctl,
                epoch: Instant::now(),
                tx,
                sources: Mutex::new(Vec::new()),
            }),
        }
    }

    /// A named emitter handle. Repeated names share one sequence
    /// counter, so the per-source stream stays gap-free no matter how
    /// many handles feed it.
    pub fn source(&self, name: &str) -> EventSource {
        let mut reg = self.shared.sources.lock().unwrap();
        if let Some((n, seq)) = reg.iter().find(|(n, _)| n.as_ref() == name) {
            return EventSource {
                shared: Arc::clone(&self.shared),
                name: Arc::clone(n),
                seq: Arc::clone(seq),
            };
        }
        let n: Arc<str> = Arc::from(name);
        let seq = Arc::new(AtomicU64::new(0));
        reg.push((Arc::clone(&n), Arc::clone(&seq)));
        EventSource { shared: Arc::clone(&self.shared), name: n, seq }
    }

    /// Attach a sink; the bus is enabled from this point on. The sink
    /// receives only records emitted after attachment.
    pub fn attach(&self, sink: Box<dyn EventSink>) {
        // Blocking send: attachment is rare and must not be lost.
        let _ = self.shared.tx.send(BusMsg::Sink(sink));
        self.shared.ctl.enabled.store(true, Ordering::SeqCst);
    }

    /// Records dropped so far anywhere in the plane (bus channel
    /// overflow or a stalled TCP subscriber's queue).
    pub fn dropped(&self) -> u64 {
        self.shared.ctl.dropped.load(Ordering::SeqCst)
    }

    /// Whether any sink is attached (the emit fast-path gate).
    pub fn enabled(&self) -> bool {
        self.shared.ctl.enabled.load(Ordering::Relaxed)
    }

    /// The per-source *next* sequence numbers: what each source's next
    /// record will carry. This is the resume point an `obs-hello`
    /// advertises to a (re)connecting subscriber.
    pub fn source_seqs(&self) -> Vec<(String, u64)> {
        self.shared
            .sources
            .lock()
            .unwrap()
            .iter()
            .map(|(n, s)| (n.to_string(), s.load(Ordering::SeqCst)))
            .collect()
    }

    /// Barrier: returns once every record emitted before this call has
    /// been delivered to (and flushed through) every attached sink.
    /// For end-of-run draining and tests — never call from a hot path.
    pub fn flush(&self) {
        let (ack_tx, ack_rx) = mpsc::sync_channel(1);
        if self.shared.tx.send(BusMsg::Flush(ack_tx)).is_ok() {
            let _ = ack_rx.recv();
        }
    }
}

impl Default for EventBus {
    fn default() -> EventBus {
        EventBus::new()
    }
}

/// A named emitter handle (cloneable; clones share the sequence
/// counter). Emitting on a disabled bus is a single atomic load.
#[derive(Clone)]
pub struct EventSource {
    shared: Arc<BusShared>,
    name: Arc<str>,
    seq: Arc<AtomicU64>,
}

impl std::fmt::Debug for EventSource {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("EventSource").field("name", &self.name).finish()
    }
}

impl EventSource {
    /// This source's name.
    pub fn name(&self) -> &str {
        &self.name
    }

    /// The bus this source feeds.
    pub fn bus(&self) -> EventBus {
        EventBus { shared: Arc::clone(&self.shared) }
    }

    /// Whether any sink is attached (one relaxed load) — gate for
    /// emission-side work that is more than building a cheap event.
    pub fn enabled(&self) -> bool {
        self.shared.ctl.enabled.load(Ordering::Relaxed)
    }

    /// Emit one event: non-blocking, drop-counting (module docs).
    pub fn emit(&self, event: Event) {
        if !self.shared.ctl.enabled.load(Ordering::Relaxed) {
            return;
        }
        let seq = self.seq.fetch_add(1, Ordering::Relaxed);
        let t_ns = self.shared.epoch.elapsed().as_nanos() as u64;
        let record = EventRecord { source: self.name.to_string(), seq, t_ns, event };
        match self.shared.tx.try_send(BusMsg::Event(record)) {
            Ok(()) => {}
            Err(TrySendError::Full(_)) | Err(TrySendError::Disconnected(_)) => {
                // The skipped seq is the drop made visible downstream.
                self.shared.ctl.dropped.fetch_add(1, Ordering::Relaxed);
            }
        }
    }
}

/// The collector loop: single consumer of the bus channel; owns the
/// sinks. Exits when every sender (bus clones + sources) is gone.
fn collect(rx: Receiver<BusMsg>, ctl: Arc<BusCtl>) {
    let mut sinks: Vec<Box<dyn EventSink>> = Vec::new();
    while let Ok(msg) = rx.recv() {
        match msg {
            BusMsg::Sink(sink) => sinks.push(sink),
            BusMsg::Flush(ack) => {
                for s in &mut sinks {
                    s.flush();
                }
                let _ = ack.send(());
            }
            BusMsg::Event(record) => {
                if sinks.is_empty() {
                    continue;
                }
                let line = encode_event_record(&record);
                sinks.retain_mut(|s| s.publish(&record, &line));
                if sinks.is_empty() {
                    // Every sink detached: flip the emit gate back off so
                    // the hot path returns to its near-zero cost.
                    ctl.enabled.store(false, Ordering::SeqCst);
                }
            }
        }
    }
}

/// JSONL file sink: one event line per record, flushed per record so a
/// `tftune dashboard --events-file` tail sees events as they land.
pub struct FileSink {
    w: std::io::BufWriter<std::fs::File>,
}

impl FileSink {
    /// Create (truncate) `path` and sink events into it.
    pub fn create(path: &std::path::Path) -> Result<FileSink> {
        if let Some(dir) = path.parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir)
                    .with_context(|| format!("creating {}", dir.display()))?;
            }
        }
        let f = std::fs::File::create(path)
            .with_context(|| format!("creating events file {}", path.display()))?;
        Ok(FileSink { w: std::io::BufWriter::new(f) })
    }
}

impl EventSink for FileSink {
    fn publish(&mut self, _record: &EventRecord, line: &str) -> bool {
        // A failed local write detaches the sink; the run itself is
        // never the observability plane's hostage.
        writeln!(self.w, "{line}").and_then(|()| self.w.flush()).is_ok()
    }

    fn flush(&mut self) {
        let _ = self.w.flush();
    }
}

/// A sink that counts records and otherwise discards them — the
/// enabled-bus overhead baseline for benches and tests.
#[derive(Clone, Default)]
pub struct CountingSink {
    /// Records seen so far.
    pub seen: Arc<AtomicU64>,
}

impl EventSink for CountingSink {
    fn publish(&mut self, _record: &EventRecord, _line: &str) -> bool {
        self.seen.fetch_add(1, Ordering::Relaxed);
        true
    }
}

/// How long the publisher waits for a subscriber's `subscribe` line
/// before giving up on the connection.
const SUBSCRIBE_TIMEOUT: std::time::Duration = std::time::Duration::from_secs(30);

/// Longest subscribe line the publisher will read before calling the
/// frame oversized and hostile.
pub const OBS_MAX_SUBSCRIBE_LINE: usize = 64 * 1024;

/// One TCP subscriber's bus-side handle: a bounded queue feeding a
/// per-subscriber writer thread. `publish` is try_send — a stalled
/// subscriber overflows its own queue (counted) and detaches only when
/// its socket actually dies.
struct SubscriberSink {
    tx: SyncSender<String>,
    dead: Arc<AtomicBool>,
    ctl: Arc<BusCtl>,
}

impl EventSink for SubscriberSink {
    fn publish(&mut self, _record: &EventRecord, line: &str) -> bool {
        if self.dead.load(Ordering::Relaxed) {
            return false;
        }
        match self.tx.try_send(line.to_string()) {
            Ok(()) => true,
            Err(TrySendError::Full(_)) => {
                self.ctl.dropped.fetch_add(1, Ordering::Relaxed);
                true // stalled, not dead: keep it attached
            }
            Err(TrySendError::Disconnected(_)) => false,
        }
    }
}

/// The daemon-side line-delimited TCP event publisher
/// (`surrogate-serve --events-addr`). Each accepted connection performs
/// the subscribe handshake (module docs §Wire framing) and then receives
/// every subsequent event line through its own bounded queue + writer
/// thread — a subscriber that stops reading stalls only itself.
pub struct EventPublisher {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    accept_handle: Option<std::thread::JoinHandle<()>>,
}

impl EventPublisher {
    /// Bind `addr` and start accepting subscribers for `bus`'s stream,
    /// with [`DEFAULT_SUBSCRIBER_QUEUE`]-deep per-subscriber queues.
    pub fn bind(addr: &str, bus: &EventBus) -> Result<EventPublisher> {
        EventPublisher::bind_with_queue(addr, bus, DEFAULT_SUBSCRIBER_QUEUE)
    }

    /// [`EventPublisher::bind`] with an explicit per-subscriber queue
    /// bound (chaos tests shrink it to force overflow deterministically).
    pub fn bind_with_queue(addr: &str, bus: &EventBus, queue: usize) -> Result<EventPublisher> {
        let listener = TcpListener::bind(addr)
            .with_context(|| format!("binding events publisher {addr}"))?;
        let local = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let accept_stop = Arc::clone(&stop);
        let accept_bus = bus.clone();
        let handle = std::thread::Builder::new()
            .name("obs-publisher".into())
            .spawn(move || {
                for conn in listener.incoming() {
                    if accept_stop.load(Ordering::SeqCst) {
                        break;
                    }
                    let Ok(stream) = conn else { continue };
                    let bus = accept_bus.clone();
                    let q = queue.max(1);
                    std::thread::Builder::new()
                        .name("obs-subscriber".into())
                        .spawn(move || handle_subscriber(stream, bus, q))
                        .ok();
                }
            })
            .expect("spawning the events publisher accept loop");
        Ok(EventPublisher { addr: local, stop, accept_handle: Some(handle) })
    }

    /// The bound address (useful with `:0`).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Stop accepting subscribers and join the accept loop. Live
    /// subscriber streams keep running until their sockets close.
    pub fn stop(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        // Unblock accept() with a throwaway connection.
        let _ = TcpStream::connect(self.addr);
        if let Some(h) = self.accept_handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for EventPublisher {
    fn drop(&mut self) {
        self.stop();
    }
}

/// One subscriber connection: handshake, then stream until death.
/// Every failure mode is strictly per-connection.
fn handle_subscriber(stream: TcpStream, bus: EventBus, queue: usize) {
    let _ = stream.set_nodelay(true);
    let _ = stream.set_read_timeout(Some(SUBSCRIBE_TIMEOUT));
    let mut writer = match stream.try_clone() {
        Ok(w) => w,
        Err(_) => return,
    };

    // Read the subscribe line with a hard size cap: an oversized or
    // unterminated frame is hostile and earns an error + close.
    let line = match read_capped_line(&stream, OBS_MAX_SUBSCRIBE_LINE) {
        Ok(Some(line)) => line,
        Ok(None) | Err(_) => return, // EOF/timeout before subscribing
    };
    if let Err(reason) = crate::server::proto::decode_obs_subscribe(line.trim_end()) {
        let _ = writeln!(writer, "{}", crate::server::proto::encode_obs_error(&reason));
        return;
    }

    // The hello: cumulative drop counter + per-source resume points.
    let hello = crate::server::proto::encode_obs_hello(bus.dropped(), &bus.source_seqs());
    if writeln!(writer, "{hello}").is_err() {
        return;
    }

    // Attach: a bounded queue into a blocking writer thread. The writer
    // thread is the only place a stalled socket blocks.
    let (tx, rx) = mpsc::sync_channel::<String>(queue);
    let dead = Arc::new(AtomicBool::new(false));
    let sink = SubscriberSink {
        tx,
        dead: Arc::clone(&dead),
        ctl: Arc::clone(&bus.shared.ctl),
    };
    bus.attach(Box::new(sink));
    while let Ok(line) = rx.recv() {
        if writeln!(writer, "{line}").is_err() {
            dead.store(true, Ordering::SeqCst);
            break;
        }
    }
    dead.store(true, Ordering::SeqCst);
}

/// Read one `\n`-terminated line from `stream`, refusing to buffer more
/// than `cap` bytes. `Ok(None)` = EOF before any data.
fn read_capped_line(stream: &TcpStream, cap: usize) -> std::io::Result<Option<String>> {
    use std::io::Read;
    let mut buf = Vec::new();
    let mut byte = [0u8; 1];
    let mut reader = stream;
    loop {
        let n = reader.read(&mut byte)?;
        if n == 0 {
            return if buf.is_empty() {
                Ok(None)
            } else {
                Ok(Some(String::from_utf8_lossy(&buf).into_owned()))
            };
        }
        if byte[0] == b'\n' {
            return Ok(Some(String::from_utf8_lossy(&buf).into_owned()));
        }
        buf.push(byte[0]);
        if buf.len() > cap {
            return Err(std::io::Error::new(
                std::io::ErrorKind::InvalidData,
                "subscribe line exceeds the frame cap",
            ));
        }
    }
}

/// Read every event record out of a JSONL events file, in order.
/// Undecodable lines are errors — a recorded stream is a contract.
pub fn read_events_file(path: &std::path::Path) -> Result<Vec<EventRecord>> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading events file {}", path.display()))?;
    let mut out = Vec::new();
    for (i, line) in text.lines().enumerate() {
        if line.trim().is_empty() {
            continue;
        }
        out.push(
            decode_event_record(line)
                .map_err(|e| anyhow::anyhow!("events line {}: {e}", i + 1))?,
        );
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_records() -> Vec<EventRecord> {
        vec![
            EventRecord {
                source: "session".into(),
                seq: 0,
                t_ns: 17,
                event: Event::TrialIssued { trial: 3 },
            },
            EventRecord {
                source: "session".into(),
                seq: 1,
                t_ns: 42,
                event: Event::TrialMeasured {
                    trial: 3,
                    config: vec![8, 64, -2],
                    value: 0.1 + 0.2, // a value with no short decimal form
                    cost_s: 1.5e-3,
                    objectives: vec![f64::MIN_POSITIVE, -1.25],
                },
            },
            EventRecord {
                source: "engine".into(),
                seq: 0,
                t_ns: 99,
                event: Event::AskEnd { issued: 4, ns: 123_456_789 },
            },
            EventRecord {
                source: "daemon".into(),
                seq: 7,
                t_ns: 1,
                event: Event::SpaceCreated { fingerprint: 0xdead_beef_0123_4567, dim: 5 },
            },
            EventRecord {
                source: "surrogate".into(),
                seq: 2,
                t_ns: 5,
                event: Event::SurrogateDrain { drained: 3, total: 12, wait_ns: 800 },
            },
            EventRecord {
                source: "persist".into(),
                seq: 0,
                t_ns: 6,
                event: Event::WalSync { records: 40 },
            },
        ]
    }

    #[test]
    fn record_codec_round_trips_bit_exactly() {
        for r in sample_records() {
            let line = encode_event_record(&r);
            let back = decode_event_record(&line).unwrap();
            assert_eq!(back, r, "line: {line}");
            if let (
                Event::TrialMeasured { value: a, .. },
                Event::TrialMeasured { value: b, .. },
            ) = (&r.event, &back.event)
            {
                assert_eq!(a.to_bits(), b.to_bits());
            }
        }
    }

    #[test]
    fn decoder_refuses_garbage() {
        assert!(decode_event_record("not json").is_err());
        assert!(decode_event_record("{}").is_err());
        assert!(decode_event_record(r#"{"src":"s","seq":0,"t_ns":0,"ev":"mystery"}"#).is_err());
        assert!(
            decode_event_record(r#"{"src":"s","seq":0,"t_ns":0,"ev":"trial-issued"}"#).is_err(),
            "trial-issued without a trial id must not decode"
        );
    }

    #[test]
    fn disabled_bus_emits_nothing_and_counts_nothing() {
        let bus = EventBus::new();
        let src = bus.source("test");
        for _ in 0..1000 {
            src.emit(Event::SurrogateTell { pending: 1 });
        }
        bus.flush();
        assert_eq!(bus.dropped(), 0);
        assert_eq!(
            bus.source_seqs(),
            vec![("test".to_string(), 0)],
            "a disabled bus must not consume sequence numbers"
        );
    }

    #[test]
    fn attached_sink_sees_every_record_in_order() {
        let bus = EventBus::new();
        let sink = CountingSink::default();
        let seen = Arc::clone(&sink.seen);
        bus.attach(Box::new(sink));
        let src = bus.source("s");
        for i in 0..500 {
            src.emit(Event::SurrogateTell { pending: i });
        }
        bus.flush();
        assert_eq!(seen.load(Ordering::SeqCst), 500);
        assert_eq!(bus.dropped(), 0);
        assert_eq!(bus.source_seqs(), vec![("s".to_string(), 500)]);
    }

    #[test]
    fn overflow_drops_and_counts_instead_of_blocking() {
        // A 4-slot bus with a sink that blocks until released: emits
        // beyond the bound must return immediately and count drops.
        struct Gate(Arc<AtomicBool>);
        impl EventSink for Gate {
            fn publish(&mut self, _r: &EventRecord, _l: &str) -> bool {
                while !self.0.load(Ordering::SeqCst) {
                    std::thread::sleep(std::time::Duration::from_millis(1));
                }
                true
            }
        }
        let bus = EventBus::with_capacity(4);
        let open = Arc::new(AtomicBool::new(false));
        bus.attach(Box::new(Gate(Arc::clone(&open))));
        let src = bus.source("s");
        let start = Instant::now();
        for i in 0..64 {
            src.emit(Event::SurrogateTell { pending: i });
        }
        let elapsed = start.elapsed();
        assert!(
            elapsed < std::time::Duration::from_millis(500),
            "emit blocked on a wedged sink ({elapsed:?})"
        );
        assert!(bus.dropped() > 0, "overflow must be counted");
        open.store(true, Ordering::SeqCst);
        bus.flush();
        // Seq numbers kept advancing through the drops: the gap is the
        // visible record of what was lost.
        assert_eq!(bus.source_seqs(), vec![("s".to_string(), 64)]);
    }

    #[test]
    fn same_name_shares_one_sequence() {
        let bus = EventBus::new();
        bus.attach(Box::new(CountingSink::default()));
        let a = bus.source("shared");
        let b = bus.source("shared");
        a.emit(Event::SurrogateTell { pending: 0 });
        b.emit(Event::SurrogateTell { pending: 1 });
        bus.flush();
        assert_eq!(bus.source_seqs(), vec![("shared".to_string(), 2)]);
    }

    #[test]
    fn file_sink_round_trips_through_the_reader() {
        let dir = std::env::temp_dir().join("tftune_obs_filesink");
        std::fs::remove_dir_all(&dir).ok();
        let path = dir.join("events.jsonl");
        let bus = EventBus::new();
        bus.attach(Box::new(FileSink::create(&path).unwrap()));
        let src = bus.source("s");
        let records = sample_records();
        for r in &records {
            src.emit(r.event.clone());
        }
        bus.flush();
        let read = read_events_file(&path).unwrap();
        assert_eq!(read.len(), records.len());
        for (got, want) in read.iter().zip(&records) {
            assert_eq!(got.event, want.event);
            assert_eq!(got.source, "s");
        }
        std::fs::remove_dir_all(&dir).ok();
    }
}
