//! The target-side daemon of the paper's host/target split (Fig. 4).
//!
//! The optimization framework ("host") runs the algorithm engines; the
//! system under test ("target") runs this daemon, which applies requested
//! configurations and reports measurements back over a JSON-lines TCP
//! protocol (`proto`). The separation keeps the tuner's compute from
//! interfering with workload measurements and lets a weak host machine
//! drive a powerful target — exactly the paper's deployment.
//!
//! Connections are *pipelined* for trial-tagged requests: the reader keeps
//! decoding while earlier tagged `evaluate` requests are still being
//! measured, and each tagged response is written as soon as its measurement
//! finishes — so a host can keep several trials in flight per connection
//! and transport latency overlaps measurement. Untagged (legacy) evaluate
//! requests are answered inline, strictly in request order, preserving the
//! pre-ask/tell protocol contract. The single system under test is always
//! serialised behind a mutex (measurements must not perturb each other);
//! run one daemon per machine and give the session several addresses for
//! true measurement parallelism.
//!
//! std::net + one thread per connection (tokio is not vendored in this
//! offline image; the protocol is line-oriented and trivially blocking).
//!
//! On the host side, every measurement a daemon reports is told back to
//! the engine and — for BO — lands in the shared surrogate factor
//! (`gp::SharedSurrogate`) in arrival order, so a fleet of daemons
//! sharded across machines amortises one GP rather than refitting per
//! connection. See `ARCHITECTURE.md` §"The shared surrogate".

pub mod proto;

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

use anyhow::{Context, Result};

use crate::evaluator::Evaluator;
use crate::space::SearchSpace;
use proto::{decode_request, encode_response, Request, Response};

/// Shared server state.
struct Shared {
    evaluator: Mutex<Box<dyn Evaluator + Send>>,
    space: SearchSpace,
    served: AtomicUsize,
    shutdown: AtomicBool,
}

/// A running target daemon.
pub struct TargetServer {
    listener: TcpListener,
    shared: Arc<Shared>,
}

impl TargetServer {
    /// Bind to `addr` (e.g. "127.0.0.1:0" for an ephemeral port).
    pub fn bind(
        addr: &str,
        space: SearchSpace,
        evaluator: Box<dyn Evaluator + Send>,
    ) -> Result<TargetServer> {
        let listener = TcpListener::bind(addr).with_context(|| format!("binding {addr}"))?;
        Ok(TargetServer {
            listener,
            shared: Arc::new(Shared {
                evaluator: Mutex::new(evaluator),
                space,
                served: AtomicUsize::new(0),
                shutdown: AtomicBool::new(false),
            }),
        })
    }

    pub fn local_addr(&self) -> Result<std::net::SocketAddr> {
        Ok(self.listener.local_addr()?)
    }

    /// Serve until a shutdown request arrives. Blocking; one thread per
    /// connection.
    pub fn serve(self) -> Result<usize> {
        let mut handles = Vec::new();
        for stream in self.listener.incoming() {
            if self.shared.shutdown.load(Ordering::SeqCst) {
                break;
            }
            let stream = stream?;
            // see RemoteEvaluator::connect — line-oriented protocol needs
            // nodelay on both ends to dodge Nagle/delayed-ACK stalls
            let _ = stream.set_nodelay(true);
            let shared = Arc::clone(&self.shared);
            handles.push(std::thread::spawn(move || handle_connection(stream, &shared)));
            if self.shared.shutdown.load(Ordering::SeqCst) {
                break;
            }
        }
        for h in handles {
            let _ = h.join();
        }
        Ok(self.shared.served.load(Ordering::SeqCst))
    }

    /// Spawn the server on a background thread; returns (addr, handle).
    pub fn spawn(self) -> Result<(std::net::SocketAddr, std::thread::JoinHandle<Result<usize>>)>
    {
        let addr = self.local_addr()?;
        let handle = std::thread::spawn(move || self.serve());
        Ok((addr, handle))
    }
}

/// Serialise one response onto the shared connection writer.
fn write_response(writer: &Mutex<TcpStream>, resp: &Response, shared: &Shared) -> bool {
    let line = encode_response(resp, &shared.space);
    let mut w = writer.lock().unwrap();
    writeln!(w, "{line}").is_ok()
}

/// Run one measurement on the shared system under test.
fn evaluate_response(
    shared: &Shared,
    config: crate::space::Config,
    trial: Option<u64>,
) -> Response {
    let t0 = std::time::Instant::now();
    match shared.evaluator.lock().unwrap().evaluate(&config) {
        Ok(value) => {
            shared.served.fetch_add(1, Ordering::SeqCst);
            Response::Result { value, cost_s: t0.elapsed().as_secs_f64(), config, trial }
        }
        Err(e) => Response::Error { message: format!("evaluation failed: {e}"), trial },
    }
}

fn handle_connection(stream: TcpStream, shared: &Shared) {
    let writer = match stream.try_clone() {
        Ok(w) => Mutex::new(w),
        Err(_) => return,
    };
    let reader = BufReader::new(stream);
    // Scoped workers let every in-flight evaluate borrow `shared` and the
    // connection writer: the reader keeps pulling pipelined requests while
    // measurements run, and responses go out tagged in completion order.
    std::thread::scope(|scope| {
        for line in reader.lines() {
            let line = match line {
                Ok(l) => l,
                Err(_) => break,
            };
            if line.trim().is_empty() {
                continue;
            }
            match decode_request(&line, &shared.space) {
                Err(e) => {
                    if !write_response(
                        &writer,
                        &Response::Error { message: e, trial: None },
                        shared,
                    ) {
                        break;
                    }
                }
                Ok(Request::Describe) => {
                    let desc = shared.evaluator.lock().unwrap().describe();
                    if !write_response(
                        &writer,
                        &Response::Target { description: desc },
                        shared,
                    ) {
                        break;
                    }
                }
                // Untagged (legacy) evaluate: answered inline so responses
                // stay in request order, exactly like the pre-pipelining
                // server — an in-order client pairs them positionally.
                Ok(Request::Evaluate { config, trial: None }) => {
                    let resp = evaluate_response(shared, config, None);
                    if !write_response(&writer, &resp, shared) {
                        break;
                    }
                }
                // Tagged evaluate: measured on a scoped worker and written
                // in completion order; the echoed trial id pairs it.
                Ok(Request::Evaluate { config, trial: trial @ Some(_) }) => {
                    let writer = &writer;
                    scope.spawn(move || {
                        let resp = evaluate_response(shared, config, trial);
                        write_response(writer, &resp, shared);
                    });
                }
                Ok(Request::Shutdown) => {
                    shared.shutdown.store(true, Ordering::SeqCst);
                    write_response(&writer, &Response::Bye, shared);
                    // poke the accept loop so serve() notices the flag
                    if let Ok(addr) = writer.lock().unwrap().local_addr() {
                        let _ = TcpStream::connect(addr);
                    }
                    break;
                }
            }
        }
        // scope joins any still-running evaluations before the connection
        // closes, so their responses are flushed first.
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::evaluator::SimEvaluator;
    use crate::sim::ModelId;
    use std::io::{BufRead, BufReader, Write};

    fn start() -> (std::net::SocketAddr, std::thread::JoinHandle<Result<usize>>, SearchSpace)
    {
        let model = ModelId::NcfFp32;
        let space = model.space();
        let server = TargetServer::bind(
            "127.0.0.1:0",
            space.clone(),
            Box::new(SimEvaluator::new(model, 9)),
        )
        .unwrap();
        let (addr, handle) = server.spawn().unwrap();
        (addr, handle, space)
    }

    fn send(addr: std::net::SocketAddr, lines: &[String]) -> Vec<String> {
        let mut s = TcpStream::connect(addr).unwrap();
        for l in lines {
            writeln!(s, "{l}").unwrap();
        }
        let reader = BufReader::new(s.try_clone().unwrap());
        let mut out = Vec::new();
        for line in reader.lines().take(lines.len()) {
            out.push(line.unwrap());
        }
        drop(s);
        out
    }

    #[test]
    fn describe_evaluate_shutdown() {
        let (addr, handle, space) = start();
        let resp = send(
            addr,
            &[
                proto::encode_request(&Request::Describe, &space),
                proto::encode_request(
                    &Request::Evaluate { config: vec![1, 8, 128, 0, 8], trial: None },
                    &space,
                ),
            ],
        );
        let r0 = proto::decode_response(&resp[0], &space).unwrap();
        assert!(matches!(r0, Response::Target { .. }));
        match proto::decode_response(&resp[1], &space).unwrap() {
            Response::Result { value, config, trial, .. } => {
                assert!(value > 0.0);
                assert_eq!(config, vec![1, 8, 128, 0, 8]);
                assert_eq!(trial, None);
            }
            other => panic!("unexpected {other:?}"),
        }
        // shutdown
        let _ = send(addr, &[proto::encode_request(&Request::Shutdown, &space)]);
        let served = handle.join().unwrap().unwrap();
        assert_eq!(served, 1);
    }

    #[test]
    fn pipelined_trials_come_back_tagged() {
        let (addr, handle, space) = start();
        // Fire four tagged evaluate requests before reading any response.
        let reqs: Vec<String> = (0..4u64)
            .map(|id| {
                proto::encode_request(
                    &Request::Evaluate {
                        config: vec![1, 8, 128, 0, 8 + id as i64],
                        trial: Some(id),
                    },
                    &space,
                )
            })
            .collect();
        let resp = send(addr, &reqs);
        assert_eq!(resp.len(), 4);
        let mut ids = Vec::new();
        for line in &resp {
            match proto::decode_response(line, &space).unwrap() {
                Response::Result { value, cost_s, trial, .. } => {
                    assert!(value > 0.0);
                    assert!(cost_s >= 0.0);
                    ids.push(trial.expect("tagged request must get a tagged response"));
                }
                other => panic!("unexpected {other:?}"),
            }
        }
        // Completion order may differ from issue order; the id *set* must
        // match exactly.
        ids.sort_unstable();
        assert_eq!(ids, vec![0, 1, 2, 3]);
        let _ = send(addr, &[proto::encode_request(&Request::Shutdown, &space)]);
        let served = handle.join().unwrap().unwrap();
        assert_eq!(served, 4);
    }

    #[test]
    fn garbage_request_gets_error_response() {
        let (addr, handle, space) = start();
        let resp = send(addr, &["this is not json".to_string()]);
        match proto::decode_response(&resp[0], &space).unwrap() {
            Response::Error { .. } => {}
            other => panic!("unexpected {other:?}"),
        }
        let _ = send(addr, &[proto::encode_request(&Request::Shutdown, &space)]);
        let _ = handle.join();
    }
}
