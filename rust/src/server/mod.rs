//! The target-side daemon of the paper's host/target split (Fig. 4).
//!
//! The optimization framework ("host") runs the algorithm engines; the
//! system under test ("target") runs this daemon, which applies requested
//! configurations and reports measurements back over a JSON-lines TCP
//! protocol (`proto`). The separation keeps the tuner's compute from
//! interfering with workload measurements and lets a weak host machine
//! drive a powerful target — exactly the paper's deployment.
//!
//! Connections are *pipelined* for trial-tagged requests: the reader keeps
//! decoding while earlier tagged `evaluate` requests are still being
//! measured, and each tagged response is written as soon as its measurement
//! finishes — so a host can keep several trials in flight per connection
//! and transport latency overlaps measurement. Untagged (legacy) evaluate
//! requests are answered inline, strictly in request order, preserving the
//! pre-ask/tell protocol contract. The single system under test is always
//! serialised behind a mutex (measurements must not perturb each other);
//! run one daemon per machine and give the session several addresses for
//! true measurement parallelism.
//!
//! std::net + one thread per connection (tokio is not vendored in this
//! offline image; the protocol is line-oriented and trivially blocking).
//!
//! On the host side, every measurement a daemon reports is told back to
//! the engine and — for BO — lands in the shared surrogate factor
//! (`gp::SharedSurrogate`) in arrival order, so a fleet of daemons
//! sharded across machines amortises one GP rather than refitting per
//! connection. See `ARCHITECTURE.md` §"The shared surrogate".
//!
//! # The surrogate service
//!
//! A daemon can additionally (or exclusively) host the **authoritative
//! shared factor** for a fleet of tuner processes: attach a
//! [`SharedSurrogate`] via [`TargetServer::with_surrogate`] (or start a
//! dedicated one with [`TargetServer::bind_surrogate_only`] / the
//! `surrogate-serve` CLI command) and the protocol-v2 surrogate plane
//! (`proto` docs) activates on every connection. `tell-obs` lines fold
//! into the served factor in arrival order; `sync-factor` exports the
//! catch-up [`SurrogateDelta`](crate::gp::SurrogateDelta) — observation
//! rows plus the packed Cholesky suffix, so replicas import instead of
//! re-factoring; `ask-lease`/`retract-lease` maintain each connection's
//! in-flight constant-liar points, which are served back to *other*
//! connections in their deltas and **expire when the owning connection
//! closes** — a crashed tuner cannot leave phantom fantasies behind.

pub mod proto;

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

use anyhow::{Context, Result};

use crate::evaluator::Evaluator;
use crate::gp::{GpHyper, SharedSurrogate};
use crate::space::SearchSpace;
use proto::{
    decode_request, decode_surrogate_request, encode_response, encode_surrogate_response,
    Request, Response, SurrogateRequest, SurrogateResponse, PROTOCOL_VERSION,
};

/// One connection's published constant-liar lease.
struct LeaseEntry {
    id: u64,
    /// Owning connection — leases are served only to *other* connections
    /// and dropped when this one closes.
    conn: u64,
    points: Vec<(Vec<f64>, f64)>,
}

#[derive(Default)]
struct LeaseTable {
    next_id: u64,
    entries: Vec<LeaseEntry>,
}

/// Shared server state.
struct Shared {
    evaluator: Mutex<Box<dyn Evaluator + Send>>,
    space: SearchSpace,
    served: AtomicUsize,
    shutdown: AtomicBool,
    /// The authoritative shared factor, when this daemon is a surrogate
    /// service (module docs).
    surrogate: Option<SharedSurrogate>,
    leases: Mutex<LeaseTable>,
    /// Connection-id allocator (lease ownership / expiry).
    conns: AtomicU64,
}

/// A running target daemon.
pub struct TargetServer {
    listener: TcpListener,
    shared: Arc<Shared>,
}

impl TargetServer {
    /// Bind to `addr` (e.g. "127.0.0.1:0" for an ephemeral port).
    pub fn bind(
        addr: &str,
        space: SearchSpace,
        evaluator: Box<dyn Evaluator + Send>,
    ) -> Result<TargetServer> {
        let listener = TcpListener::bind(addr).with_context(|| format!("binding {addr}"))?;
        Ok(TargetServer {
            listener,
            shared: Arc::new(Shared {
                evaluator: Mutex::new(evaluator),
                space,
                served: AtomicUsize::new(0),
                shutdown: AtomicBool::new(false),
                surrogate: None,
                leases: Mutex::new(LeaseTable::default()),
                conns: AtomicU64::new(0),
            }),
        })
    }

    /// Host `surrogate` as the authoritative shared factor next to the
    /// measurement daemon (module docs: the surrogate service). Must be
    /// called before [`TargetServer::serve`]/[`TargetServer::spawn`].
    /// Keep a clone of the handle to observe or reuse the factor after
    /// the daemon shuts down.
    pub fn with_surrogate(mut self, surrogate: SharedSurrogate) -> TargetServer {
        Arc::get_mut(&mut self.shared)
            .expect("attach the surrogate before serving")
            .surrogate = Some(surrogate);
        self
    }

    /// Bind a dedicated surrogate service: a daemon that hosts the
    /// authoritative factor (fresh, conditioned with `hyper`) and no
    /// measurement target — `evaluate` requests get a clean error.
    /// Returns the daemon and a local handle to the served factor.
    pub fn bind_surrogate_only(
        addr: &str,
        hyper: GpHyper,
    ) -> Result<(TargetServer, SharedSurrogate)> {
        TargetServer::bind_surrogate_with(addr, SharedSurrogate::new(hyper))
    }

    /// Like [`TargetServer::bind_surrogate_only`], but host an *existing*
    /// surrogate — e.g. one restored by
    /// [`persist::recover`](crate::persist::recover()) — instead of a
    /// fresh one. The served lease table starts empty either way: leases
    /// are liveness state scoped to live connections, so a restarted
    /// daemon forgets pre-crash leases and replicas re-publish on their
    /// next guard drop (see `gp::replica`).
    pub fn bind_surrogate_with(
        addr: &str,
        surrogate: SharedSurrogate,
    ) -> Result<(TargetServer, SharedSurrogate)> {
        let server = TargetServer::bind(
            addr,
            crate::space::threading_space(64, 1024, 64),
            Box::new(NoTarget),
        )?
        .with_surrogate(surrogate.clone());
        Ok((server, surrogate))
    }

    pub fn local_addr(&self) -> Result<std::net::SocketAddr> {
        Ok(self.listener.local_addr()?)
    }

    /// Serve until a shutdown request arrives. Blocking; one thread per
    /// connection.
    pub fn serve(self) -> Result<usize> {
        let mut handles = Vec::new();
        for stream in self.listener.incoming() {
            if self.shared.shutdown.load(Ordering::SeqCst) {
                break;
            }
            let stream = stream?;
            // see RemoteEvaluator::connect — line-oriented protocol needs
            // nodelay on both ends to dodge Nagle/delayed-ACK stalls
            let _ = stream.set_nodelay(true);
            let shared = Arc::clone(&self.shared);
            handles.push(std::thread::spawn(move || handle_connection(stream, &shared)));
            if self.shared.shutdown.load(Ordering::SeqCst) {
                break;
            }
        }
        for h in handles {
            let _ = h.join();
        }
        Ok(self.shared.served.load(Ordering::SeqCst))
    }

    /// Spawn the server on a background thread; returns (addr, handle).
    pub fn spawn(self) -> Result<(std::net::SocketAddr, std::thread::JoinHandle<Result<usize>>)>
    {
        let addr = self.local_addr()?;
        let handle = std::thread::spawn(move || self.serve());
        Ok((addr, handle))
    }
}

/// Evaluator behind [`TargetServer::bind_surrogate_only`]: a surrogate
/// service with no measurement target.
struct NoTarget;

impl Evaluator for NoTarget {
    fn evaluate(&mut self, _config: &crate::space::Config) -> Result<f64> {
        anyhow::bail!("this daemon serves only the shared surrogate; no target is attached")
    }

    fn describe(&self) -> String {
        "surrogate-only".to_string()
    }
}

/// Serialise one response onto the shared connection writer.
fn write_response(writer: &Mutex<TcpStream>, resp: &Response, shared: &Shared) -> bool {
    let line = encode_response(resp, &shared.space);
    let mut w = writer.lock().unwrap();
    writeln!(w, "{line}").is_ok()
}

/// Serve one surrogate-plane request (module docs: the surrogate
/// service). Returns false when the connection writer is gone.
fn handle_surrogate_request(
    req: SurrogateRequest,
    shared: &Shared,
    conn_id: u64,
    writer: &Mutex<TcpStream>,
) -> bool {
    let no_factor = || SurrogateResponse::Error {
        message: "this daemon hosts no shared surrogate (start one with `surrogate-serve` \
                  or attach it via TargetServer::with_surrogate)"
            .to_string(),
    };
    let resp = match req {
        // The handshake answers on any daemon — it reports the
        // *negotiated* version, min(client, server), so an old peer
        // keeps speaking its own protocol (single-objective tells)
        // against a newer daemon instead of being refused.
        SurrogateRequest::Hello { version } => {
            SurrogateResponse::HelloOk { version: version.min(PROTOCOL_VERSION) }
        }
        SurrogateRequest::TellObs { x, y, ys } => match &shared.surrogate {
            Some(s) => {
                // Fire-and-forget: queue into the served factor (enqueue
                // order across connections = arrival order here) and send
                // no response, so tells never stall the teller. Secondary
                // objective columns (v3) ride into the store with the row;
                // a v2 teller simply contributes single-objective rows.
                let mut all = Vec::with_capacity(1 + ys.len());
                all.push(y);
                all.extend(ys);
                s.tell_multi(x, all);
                return true;
            }
            None => no_factor(),
        },
        SurrogateRequest::SyncFactor { from_n } => match &shared.surrogate {
            Some(s) => match s.export_delta(from_n) {
                Some(mut d) => {
                    // Serve every *other* connection's lease points: the
                    // requester conditions its own in-flight trials
                    // itself.
                    let table = shared.leases.lock().unwrap();
                    d.leases = table
                        .entries
                        .iter()
                        .filter(|e| e.conn != conn_id)
                        .flat_map(|e| e.points.iter().cloned())
                        .collect();
                    SurrogateResponse::FactorDelta(d)
                }
                None => SurrogateResponse::Error {
                    message: format!(
                        "replica claims {from_n} rows, ahead of the served factor"
                    ),
                },
            },
            None => no_factor(),
        },
        SurrogateRequest::AskLease { points } => {
            let mut table = shared.leases.lock().unwrap();
            table.next_id += 1;
            let id = table.next_id;
            table.entries.push(LeaseEntry { id, conn: conn_id, points });
            SurrogateResponse::Lease { id }
        }
        SurrogateRequest::RetractLease { id } => {
            let mut table = shared.leases.lock().unwrap();
            table.entries.retain(|e| e.id != id || e.conn != conn_id);
            SurrogateResponse::LeaseOk { id }
        }
        SurrogateRequest::SetHyper { hyper } => match &shared.surrogate {
            Some(s) => {
                s.set_hyper(hyper);
                SurrogateResponse::HyperOk
            }
            None => no_factor(),
        },
    };
    let line = encode_surrogate_response(&resp);
    let mut w = writer.lock().unwrap();
    writeln!(w, "{line}").is_ok()
}

/// Run one measurement on the shared system under test.
fn evaluate_response(
    shared: &Shared,
    config: crate::space::Config,
    trial: Option<u64>,
) -> Response {
    let t0 = std::time::Instant::now();
    match shared.evaluator.lock().unwrap().evaluate(&config) {
        Ok(value) => {
            shared.served.fetch_add(1, Ordering::SeqCst);
            Response::Result { value, cost_s: t0.elapsed().as_secs_f64(), config, trial }
        }
        Err(e) => Response::Error { message: format!("evaluation failed: {e}"), trial },
    }
}

fn handle_connection(stream: TcpStream, shared: &Shared) {
    let writer = match stream.try_clone() {
        Ok(w) => Mutex::new(w),
        Err(_) => return,
    };
    // Lease scope: this connection's published constant-liar points live
    // exactly as long as the connection (expiry on disconnect).
    let conn_id = shared.conns.fetch_add(1, Ordering::SeqCst);
    let reader = BufReader::new(stream);
    // Scoped workers let every in-flight evaluate borrow `shared` and the
    // connection writer: the reader keeps pulling pipelined requests while
    // measurements run, and responses go out tagged in completion order.
    std::thread::scope(|scope| {
        for line in reader.lines() {
            let line = match line {
                Ok(l) => l,
                Err(_) => break,
            };
            if line.trim().is_empty() {
                continue;
            }
            match decode_request(&line, &shared.space) {
                Err(e) => {
                    // Not an evaluate-plane message: try the surrogate
                    // plane before reporting a decode error.
                    match decode_surrogate_request(&line) {
                        Ok(sreq) => {
                            if !handle_surrogate_request(sreq, shared, conn_id, &writer) {
                                break;
                            }
                        }
                        Err(_) => {
                            if !write_response(
                                &writer,
                                &Response::Error { message: e, trial: None },
                                shared,
                            ) {
                                break;
                            }
                        }
                    }
                }
                Ok(Request::Describe) => {
                    let desc = shared.evaluator.lock().unwrap().describe();
                    if !write_response(
                        &writer,
                        &Response::Target { description: desc },
                        shared,
                    ) {
                        break;
                    }
                }
                // Untagged (legacy) evaluate: answered inline so responses
                // stay in request order, exactly like the pre-pipelining
                // server — an in-order client pairs them positionally.
                Ok(Request::Evaluate { config, trial: None }) => {
                    let resp = evaluate_response(shared, config, None);
                    if !write_response(&writer, &resp, shared) {
                        break;
                    }
                }
                // Tagged evaluate: measured on a scoped worker and written
                // in completion order; the echoed trial id pairs it.
                Ok(Request::Evaluate { config, trial: trial @ Some(_) }) => {
                    let writer = &writer;
                    scope.spawn(move || {
                        let resp = evaluate_response(shared, config, trial);
                        write_response(writer, &resp, shared);
                    });
                }
                Ok(Request::Shutdown) => {
                    shared.shutdown.store(true, Ordering::SeqCst);
                    write_response(&writer, &Response::Bye, shared);
                    // poke the accept loop so serve() notices the flag
                    if let Ok(addr) = writer.lock().unwrap().local_addr() {
                        let _ = TcpStream::connect(addr);
                    }
                    break;
                }
            }
        }
        // scope joins any still-running evaluations before the connection
        // closes, so their responses are flushed first.
    });
    // Lease expiry on disconnect: a replica that died mid-batch (or never
    // retracted) stops conditioning its siblings' models right here.
    shared.leases.lock().unwrap().entries.retain(|e| e.conn != conn_id);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::evaluator::SimEvaluator;
    use crate::sim::ModelId;
    use std::io::{BufRead, BufReader, Write};

    fn start() -> (std::net::SocketAddr, std::thread::JoinHandle<Result<usize>>, SearchSpace)
    {
        let model = ModelId::NcfFp32;
        let space = model.space();
        let server = TargetServer::bind(
            "127.0.0.1:0",
            space.clone(),
            Box::new(SimEvaluator::new(model, 9)),
        )
        .unwrap();
        let (addr, handle) = server.spawn().unwrap();
        (addr, handle, space)
    }

    fn send(addr: std::net::SocketAddr, lines: &[String]) -> Vec<String> {
        let mut s = TcpStream::connect(addr).unwrap();
        for l in lines {
            writeln!(s, "{l}").unwrap();
        }
        let reader = BufReader::new(s.try_clone().unwrap());
        let mut out = Vec::new();
        for line in reader.lines().take(lines.len()) {
            out.push(line.unwrap());
        }
        drop(s);
        out
    }

    #[test]
    fn describe_evaluate_shutdown() {
        let (addr, handle, space) = start();
        let resp = send(
            addr,
            &[
                proto::encode_request(&Request::Describe, &space),
                proto::encode_request(
                    &Request::Evaluate { config: vec![1, 8, 128, 0, 8], trial: None },
                    &space,
                ),
            ],
        );
        let r0 = proto::decode_response(&resp[0], &space).unwrap();
        assert!(matches!(r0, Response::Target { .. }));
        match proto::decode_response(&resp[1], &space).unwrap() {
            Response::Result { value, config, trial, .. } => {
                assert!(value > 0.0);
                assert_eq!(config, vec![1, 8, 128, 0, 8]);
                assert_eq!(trial, None);
            }
            other => panic!("unexpected {other:?}"),
        }
        // shutdown
        let _ = send(addr, &[proto::encode_request(&Request::Shutdown, &space)]);
        let served = handle.join().unwrap().unwrap();
        assert_eq!(served, 1);
    }

    #[test]
    fn pipelined_trials_come_back_tagged() {
        let (addr, handle, space) = start();
        // Fire four tagged evaluate requests before reading any response.
        let reqs: Vec<String> = (0..4u64)
            .map(|id| {
                proto::encode_request(
                    &Request::Evaluate {
                        config: vec![1, 8, 128, 0, 8 + id as i64],
                        trial: Some(id),
                    },
                    &space,
                )
            })
            .collect();
        let resp = send(addr, &reqs);
        assert_eq!(resp.len(), 4);
        let mut ids = Vec::new();
        for line in &resp {
            match proto::decode_response(line, &space).unwrap() {
                Response::Result { value, cost_s, trial, .. } => {
                    assert!(value > 0.0);
                    assert!(cost_s >= 0.0);
                    ids.push(trial.expect("tagged request must get a tagged response"));
                }
                other => panic!("unexpected {other:?}"),
            }
        }
        // Completion order may differ from issue order; the id *set* must
        // match exactly.
        ids.sort_unstable();
        assert_eq!(ids, vec![0, 1, 2, 3]);
        let _ = send(addr, &[proto::encode_request(&Request::Shutdown, &space)]);
        let served = handle.join().unwrap().unwrap();
        assert_eq!(served, 4);
    }

    #[test]
    fn surrogate_plane_tell_sync_lease_over_tcp() {
        let (server, factor) =
            TargetServer::bind_surrogate_only("127.0.0.1:0", crate::gp::GpHyper::default())
                .unwrap();
        let (addr, handle) = server.spawn().unwrap();
        let space = crate::space::threading_space(64, 1024, 64);

        let mut s = TcpStream::connect(addr).unwrap();
        let mut reader = BufReader::new(s.try_clone().unwrap());
        fn roundtrip(
            s: &mut TcpStream,
            reader: &mut BufReader<TcpStream>,
            req: &SurrogateRequest,
        ) -> SurrogateResponse {
            writeln!(s, "{}", proto::encode_surrogate_request(req)).unwrap();
            let mut line = String::new();
            reader.read_line(&mut line).unwrap();
            proto::decode_surrogate_response(line.trim_end()).unwrap()
        }

        // Handshake negotiates min(client, server): a v2 client is
        // answered at v2, a current client at the server's version.
        match roundtrip(&mut s, &mut reader, &SurrogateRequest::Hello { version: 2 }) {
            SurrogateResponse::HelloOk { version } => assert_eq!(version, 2),
            other => panic!("unexpected {other:?}"),
        }
        match roundtrip(
            &mut s,
            &mut reader,
            &SurrogateRequest::Hello { version: PROTOCOL_VERSION },
        ) {
            SurrogateResponse::HelloOk { version } => assert_eq!(version, PROTOCOL_VERSION),
            other => panic!("unexpected {other:?}"),
        }
        // Fire-and-forget tells (no response), then a sync that must see
        // both of them in arrival order.
        for (x, y) in [(vec![0.25, 0.5], 1.0), (vec![0.75, 0.5], 2.0)] {
            writeln!(
                s,
                "{}",
                proto::encode_surrogate_request(&SurrogateRequest::TellObs {
                    x,
                    y,
                    ys: Vec::new()
                })
            )
            .unwrap();
        }
        match roundtrip(&mut s, &mut reader, &SurrogateRequest::SyncFactor { from_n: 0 }) {
            SurrogateResponse::FactorDelta(d) => {
                assert_eq!(d.total_n, 2);
                assert_eq!(d.rows.len(), 2);
                assert_eq!(d.rows[0].1, 1.0);
                assert_eq!(d.rows[1].1, 2.0);
                assert!(d.factor.is_some(), "eager prefix factor rides along");
                assert!(d.leases.is_empty(), "own leases are never served back");
            }
            other => panic!("unexpected {other:?}"),
        }
        assert_eq!(factor.len(), 2, "tells landed in the hosted factor");

        // A lease from this connection is invisible to it but visible to
        // a second connection — until this connection closes.
        match roundtrip(
            &mut s,
            &mut reader,
            &SurrogateRequest::AskLease { points: vec![(vec![0.1, 0.1], 0.0)] },
        ) {
            SurrogateResponse::Lease { .. } => {}
            other => panic!("unexpected {other:?}"),
        }
        match roundtrip(&mut s, &mut reader, &SurrogateRequest::SyncFactor { from_n: 2 }) {
            SurrogateResponse::FactorDelta(d) => assert!(d.leases.is_empty()),
            other => panic!("unexpected {other:?}"),
        }
        let mut s2 = TcpStream::connect(addr).unwrap();
        let mut reader2 = BufReader::new(s2.try_clone().unwrap());
        match roundtrip(&mut s2, &mut reader2, &SurrogateRequest::SyncFactor { from_n: 0 }) {
            SurrogateResponse::FactorDelta(d) => {
                assert_eq!(d.leases, vec![(vec![0.1, 0.1], 0.0)]);
            }
            other => panic!("unexpected {other:?}"),
        }
        // Both halves of the first connection must close for the server's
        // reader to see EOF.
        drop(s);
        drop(reader);
        // Lease expiry on disconnect (poll: the server notices EOF async).
        let mut expired = false;
        for _ in 0..200 {
            match roundtrip(&mut s2, &mut reader2, &SurrogateRequest::SyncFactor { from_n: 2 })
            {
                SurrogateResponse::FactorDelta(d) => {
                    if d.leases.is_empty() {
                        expired = true;
                        break;
                    }
                }
                other => panic!("unexpected {other:?}"),
            }
            std::thread::sleep(std::time::Duration::from_millis(5));
        }
        assert!(expired, "lease survived its connection");

        // A surrogate-only daemon refuses measurements cleanly.
        writeln!(
            s2,
            "{}",
            proto::encode_request(
                &Request::Evaluate { config: vec![1, 8, 128, 0, 8], trial: None },
                &space,
            )
        )
        .unwrap();
        let mut line = String::new();
        reader2.read_line(&mut line).unwrap();
        match proto::decode_response(line.trim_end(), &space).unwrap() {
            Response::Error { message, .. } => {
                assert!(message.contains("no target"), "{message}")
            }
            other => panic!("unexpected {other:?}"),
        }

        let _ = send(addr, &[proto::encode_request(&Request::Shutdown, &space)]);
        let _ = handle.join();
    }

    #[test]
    fn measurement_daemon_without_surrogate_refuses_the_plane() {
        let (addr, handle, space) = start();
        let mut s = TcpStream::connect(addr).unwrap();
        writeln!(
            s,
            "{}",
            proto::encode_surrogate_request(&SurrogateRequest::SyncFactor { from_n: 0 })
        )
        .unwrap();
        let mut reader = BufReader::new(s.try_clone().unwrap());
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        match proto::decode_surrogate_response(line.trim_end()).unwrap() {
            SurrogateResponse::Error { message } => {
                assert!(message.contains("hosts no shared surrogate"), "{message}")
            }
            other => panic!("unexpected {other:?}"),
        }
        drop(s);
        let _ = send(addr, &[proto::encode_request(&Request::Shutdown, &space)]);
        let _ = handle.join();
    }

    #[test]
    fn garbage_request_gets_error_response() {
        let (addr, handle, space) = start();
        let resp = send(addr, &["this is not json".to_string()]);
        match proto::decode_response(&resp[0], &space).unwrap() {
            Response::Error { .. } => {}
            other => panic!("unexpected {other:?}"),
        }
        let _ = send(addr, &[proto::encode_request(&Request::Shutdown, &space)]);
        let _ = handle.join();
    }
}
