//! The target-side daemon of the paper's host/target split (Fig. 4).
//!
//! The optimization framework ("host") runs the algorithm engines; the
//! system under test ("target") runs this daemon, which applies requested
//! configurations and reports measurements back over a JSON-lines TCP
//! protocol (`proto`). The separation keeps the tuner's compute from
//! interfering with workload measurements and lets a weak host machine
//! drive a powerful target — exactly the paper's deployment.
//!
//! std::net + one thread per connection (tokio is not vendored in this
//! offline image; the protocol is line-oriented and trivially blocking).

pub mod proto;

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

use anyhow::{Context, Result};

use crate::evaluator::Evaluator;
use crate::space::SearchSpace;
use proto::{decode_request, encode_response, Request, Response};

/// Shared server state.
struct Shared {
    evaluator: Mutex<Box<dyn Evaluator + Send>>,
    space: SearchSpace,
    served: AtomicUsize,
    shutdown: AtomicBool,
}

/// A running target daemon.
pub struct TargetServer {
    listener: TcpListener,
    shared: Arc<Shared>,
}

impl TargetServer {
    /// Bind to `addr` (e.g. "127.0.0.1:0" for an ephemeral port).
    pub fn bind(
        addr: &str,
        space: SearchSpace,
        evaluator: Box<dyn Evaluator + Send>,
    ) -> Result<TargetServer> {
        let listener = TcpListener::bind(addr).with_context(|| format!("binding {addr}"))?;
        Ok(TargetServer {
            listener,
            shared: Arc::new(Shared {
                evaluator: Mutex::new(evaluator),
                space,
                served: AtomicUsize::new(0),
                shutdown: AtomicBool::new(false),
            }),
        })
    }

    pub fn local_addr(&self) -> Result<std::net::SocketAddr> {
        Ok(self.listener.local_addr()?)
    }

    /// Serve until a shutdown request arrives. Blocking; one thread per
    /// connection.
    pub fn serve(self) -> Result<usize> {
        let mut handles = Vec::new();
        for stream in self.listener.incoming() {
            if self.shared.shutdown.load(Ordering::SeqCst) {
                break;
            }
            let stream = stream?;
            // see RemoteEvaluator::connect — line-oriented protocol needs
            // nodelay on both ends to dodge Nagle/delayed-ACK stalls
            let _ = stream.set_nodelay(true);
            let shared = Arc::clone(&self.shared);
            handles.push(std::thread::spawn(move || handle_connection(stream, &shared)));
            if self.shared.shutdown.load(Ordering::SeqCst) {
                break;
            }
        }
        for h in handles {
            let _ = h.join();
        }
        Ok(self.shared.served.load(Ordering::SeqCst))
    }

    /// Spawn the server on a background thread; returns (addr, handle).
    pub fn spawn(self) -> Result<(std::net::SocketAddr, std::thread::JoinHandle<Result<usize>>)>
    {
        let addr = self.local_addr()?;
        let handle = std::thread::spawn(move || self.serve());
        Ok((addr, handle))
    }
}

fn handle_connection(stream: TcpStream, shared: &Shared) {
    let peer = stream.peer_addr().map(|a| a.to_string()).unwrap_or_default();
    let mut writer = match stream.try_clone() {
        Ok(w) => w,
        Err(_) => return,
    };
    let reader = BufReader::new(stream);
    for line in reader.lines() {
        let line = match line {
            Ok(l) => l,
            Err(_) => break,
        };
        if line.trim().is_empty() {
            continue;
        }
        let resp = match decode_request(&line, &shared.space) {
            Err(e) => Response::Error { message: e },
            Ok(Request::Describe) => {
                let desc = shared.evaluator.lock().unwrap().describe();
                Response::Target { description: desc }
            }
            Ok(Request::Evaluate(cfg)) => {
                let result = shared.evaluator.lock().unwrap().evaluate(&cfg);
                match result {
                    Ok(value) => {
                        shared.served.fetch_add(1, Ordering::SeqCst);
                        Response::Result { value, config: cfg }
                    }
                    Err(e) => Response::Error { message: format!("evaluation failed: {e}") },
                }
            }
            Ok(Request::Shutdown) => {
                shared.shutdown.store(true, Ordering::SeqCst);
                let _ = writeln!(writer, "{}", encode_response(&Response::Bye, &shared.space));
                // poke the accept loop so serve() notices the flag
                if let Ok(addr) = writer.local_addr() {
                    let _ = TcpStream::connect(addr);
                }
                return;
            }
        };
        if writeln!(writer, "{}", encode_response(&resp, &shared.space)).is_err() {
            break;
        }
    }
    let _ = peer;
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::evaluator::SimEvaluator;
    use crate::sim::ModelId;
    use std::io::{BufRead, BufReader, Write};

    fn start() -> (std::net::SocketAddr, std::thread::JoinHandle<Result<usize>>, SearchSpace)
    {
        let model = ModelId::NcfFp32;
        let space = model.space();
        let server = TargetServer::bind(
            "127.0.0.1:0",
            space.clone(),
            Box::new(SimEvaluator::new(model, 9)),
        )
        .unwrap();
        let (addr, handle) = server.spawn().unwrap();
        (addr, handle, space)
    }

    fn send(addr: std::net::SocketAddr, lines: &[String]) -> Vec<String> {
        let mut s = TcpStream::connect(addr).unwrap();
        for l in lines {
            writeln!(s, "{l}").unwrap();
        }
        let reader = BufReader::new(s.try_clone().unwrap());
        let mut out = Vec::new();
        for line in reader.lines().take(lines.len()) {
            out.push(line.unwrap());
        }
        drop(s);
        out
    }

    #[test]
    fn describe_evaluate_shutdown() {
        let (addr, handle, space) = start();
        let resp = send(
            addr,
            &[
                proto::encode_request(&Request::Describe, &space),
                proto::encode_request(&Request::Evaluate(vec![1, 8, 128, 0, 8]), &space),
            ],
        );
        let r0 = proto::decode_response(&resp[0], &space).unwrap();
        assert!(matches!(r0, Response::Target { .. }));
        match proto::decode_response(&resp[1], &space).unwrap() {
            Response::Result { value, config } => {
                assert!(value > 0.0);
                assert_eq!(config, vec![1, 8, 128, 0, 8]);
            }
            other => panic!("unexpected {other:?}"),
        }
        // shutdown
        let _ = send(addr, &[proto::encode_request(&Request::Shutdown, &space)]);
        let served = handle.join().unwrap().unwrap();
        assert_eq!(served, 1);
    }

    #[test]
    fn garbage_request_gets_error_response() {
        let (addr, handle, space) = start();
        let resp = send(addr, &["this is not json".to_string()]);
        match proto::decode_response(&resp[0], &space).unwrap() {
            Response::Error { .. } => {}
            other => panic!("unexpected {other:?}"),
        }
        let _ = send(addr, &[proto::encode_request(&Request::Shutdown, &space)]);
        let _ = handle.join();
    }
}
