//! The target-side daemon of the paper's host/target split (Fig. 4).
//!
//! The optimization framework ("host") runs the algorithm engines; the
//! system under test ("target") runs this daemon, which applies requested
//! configurations and reports measurements back over a JSON-lines TCP
//! protocol (`proto`). The separation keeps the tuner's compute from
//! interfering with workload measurements and lets a weak host machine
//! drive a powerful target — exactly the paper's deployment.
//!
//! Connections are *pipelined* for trial-tagged requests: the reader keeps
//! decoding while earlier tagged `evaluate` requests are still being
//! measured, and each tagged response is written as soon as its measurement
//! finishes — so a host can keep several trials in flight per connection
//! and transport latency overlaps measurement. Untagged (legacy) evaluate
//! requests are answered inline, strictly in request order, preserving the
//! pre-ask/tell protocol contract. The single system under test is always
//! serialised behind a mutex (measurements must not perturb each other);
//! run one daemon per machine and give the session several addresses for
//! true measurement parallelism.
//!
//! std::net + one thread per connection (tokio is not vendored in this
//! offline image; the protocol is line-oriented and trivially blocking).
//!
//! On the host side, every measurement a daemon reports is told back to
//! the engine and — for BO — lands in the shared surrogate factor
//! (`gp::SharedSurrogate`) in arrival order, so a fleet of daemons
//! sharded across machines amortises one GP rather than refitting per
//! connection. See `ARCHITECTURE.md` §"The shared surrogate".
//!
//! # The surrogate service
//!
//! A daemon can additionally (or exclusively) host the **authoritative
//! shared factor** for a fleet of tuner processes: attach a
//! [`SharedSurrogate`] via [`TargetServer::with_surrogate`] (or start a
//! dedicated one with [`TargetServer::bind_surrogate_only`] / the
//! `surrogate-serve` CLI command) and the protocol-v2 surrogate plane
//! (`proto` docs) activates on every connection. `tell-obs` lines fold
//! into the served factor in arrival order; `sync-factor` exports the
//! catch-up [`SurrogateDelta`](crate::gp::SurrogateDelta) — observation
//! rows plus the packed Cholesky suffix, so replicas import instead of
//! re-factoring; `ask-lease`/`retract-lease` maintain each connection's
//! in-flight constant-liar points, which are served back to *other*
//! connections in their deltas and **expire when the owning connection
//! closes** — a crashed tuner cannot leave phantom fantasies behind.
//!
//! # The fleet service (protocol v4)
//!
//! One daemon can host **many search spaces at once**: each space — keyed
//! by [`SearchSpace::fingerprint`] — owns an independent factor, lease
//! table and model lock, so spaces never contend with each other. A v4
//! `hello` carrying a fingerprint binds the connection to its space,
//! lazily creating it on first contact (recovering it from its
//! `--state-dir` namespace when one exists); v2/v3 peers, which send no
//! fingerprint, keep conditioning the daemon's *default* space exactly as
//! before. A hello the fleet cannot honour — dimension conflict under an
//! existing fingerprint, fleet at [`FleetOptions::max_spaces`] — is
//! answered with a typed `hello-err` instead of the old silent
//! drop-with-warning, and only that connection is affected: sibling
//! spaces keep serving. With [`FleetOptions::idle_ttl`] set, a background
//! sweeper evicts spaces no connection has bound for that long,
//! snapshotting them to their state-dir namespace first (when the fleet
//! is durable) so a later hello restores them bit-identically.
//!
//! The space map itself is an `RwLock`: hellos and rebinds to *known*
//! spaces share a read lock (a hello storm from a large fleet no longer
//! serialises behind one mutex), and only space creation, recovery and
//! eviction take the write lock.
//!
//! With [`FleetOptions::max_rows_per_space`] the daemon also polices how
//! big any hosted factor may grow. What happens at the cap is the
//! [`FactorTier`] policy (`surrogate-serve --surrogate`): `Auto` (the
//! default) converts the space's factor to the **sharded scaling tier**
//! ([`crate::gp::ShardedGp`]) in place, so tells keep landing at O(cap²)
//! amortised cost; `Sharded` runs every space on that tier from its
//! first row; `Exact` pins the flat factor and answers further tells
//! with a typed error (the connection closes; the teller redials and
//! re-hellos).

pub mod proto;

use std::collections::HashMap;
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, OnceLock, RwLock};
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::evaluator::Evaluator;
use crate::gp::{GpHyper, SharedSurrogate};
use crate::obs::{Event, EventSource};
use crate::space::SearchSpace;
use crate::util::linalg::packed_len;
use proto::{
    decode_request, decode_surrogate_request, encode_response, encode_surrogate_response,
    Request, Response, SurrogateRequest, SurrogateResponse, PROTOCOL_VERSION,
};

/// One connection's published constant-liar lease.
struct LeaseEntry {
    id: u64,
    /// Owning connection — leases are served only to *other* connections
    /// and dropped when this one closes.
    conn: u64,
    points: Vec<(Vec<f64>, f64)>,
}

#[derive(Default)]
struct LeaseTable {
    next_id: u64,
    entries: Vec<LeaseEntry>,
}

/// One hosted search space: an independent factor + lease table (+ its
/// own durability journal when the fleet has a state dir). Spaces share
/// nothing but the listener — tells into one never take another's locks.
struct SpaceState {
    fingerprint: u64,
    surrogate: SharedSurrogate,
    leases: Mutex<LeaseTable>,
    /// Declared row dimension (0 = not yet known). Set by the first
    /// fingerprinted hello or by recovery; a later hello declaring a
    /// different dimension under the same fingerprint is refused.
    dim: AtomicUsize,
    /// Connections currently bound to this space.
    active: AtomicUsize,
    /// When `active` last dropped to zero — the idle clock the eviction
    /// sweeper reads.
    last_release: Mutex<Instant>,
    /// Per-space journal for lazily created spaces. The *default* space's
    /// persistence is owned by whoever attached it (e.g. `main.rs`), not
    /// here.
    persist: Option<crate::persist::Persistence>,
}

impl SpaceState {
    fn new(fingerprint: u64, surrogate: SharedSurrogate, dim: usize) -> SpaceState {
        SpaceState {
            fingerprint,
            surrogate,
            leases: Mutex::new(LeaseTable::default()),
            dim: AtomicUsize::new(dim),
            active: AtomicUsize::new(0),
            last_release: Mutex::new(Instant::now()),
            persist: None,
        }
    }
}

/// Which factor engine hosted spaces run
/// (`surrogate-serve --surrogate auto|exact|sharded`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FactorTier {
    /// Flat exact factor until [`FleetOptions::max_rows_per_space`], then
    /// convert the space to the sharded tier in place. The default.
    Auto,
    /// Always the flat exact factor; at the row cap further tells are
    /// refused with a typed error.
    Exact,
    /// The sharded scaling tier from the first row.
    Sharded,
}

impl FactorTier {
    /// Parse a CLI spelling. `exact`/`native` name the flat engine,
    /// matching the `tune --surrogate` aliases.
    pub fn parse(s: &str) -> Option<FactorTier> {
        match s {
            "auto" => Some(FactorTier::Auto),
            "exact" | "native" => Some(FactorTier::Exact),
            "sharded" => Some(FactorTier::Sharded),
            _ => None,
        }
    }
}

/// Fleet knobs (`surrogate-serve --max-spaces / --space-idle-secs /
/// --max-rows-per-space / --surrogate`).
#[derive(Debug, Clone)]
pub struct FleetOptions {
    /// Most spaces hosted at once, the default space included. A
    /// fingerprinted hello that would create one more gets `hello-err`.
    pub max_spaces: usize,
    /// Evict a space after no connection has bound it for this long
    /// (snapshotting it first when durable). `None` — the default — never
    /// evicts. The default space is never evicted.
    pub idle_ttl: Option<Duration>,
    /// Root state directory: lazily created spaces journal into
    /// `space-<16 hex>/` namespaces under it (see
    /// [`crate::persist::space_dir`]) and are recovered from there on
    /// boot and on re-hello after eviction.
    pub state_dir: Option<PathBuf>,
    /// WAL fsync cadence for per-space journals
    /// ([`crate::persist::PersistOptions::fsync_every`]).
    pub fsync_every: usize,
    /// Hyperparameters for spaces born without recoverable state.
    pub default_hyper: GpHyper,
    /// Row cap per hosted space. `None` — the default — never caps. At
    /// the cap, [`FleetOptions::tier`] decides between converting the
    /// space to the sharded tier and refusing further tells.
    pub max_rows_per_space: Option<usize>,
    /// Factor-engine policy (see [`FactorTier`]).
    pub tier: FactorTier,
    /// Shard leaf capacity for spaces on the sharded tier
    /// ([`crate::gp::ShardedGp`]).
    pub shard_cap: usize,
    /// Posterior blend breadth for spaces on the sharded tier.
    pub blend_k: usize,
}

impl Default for FleetOptions {
    fn default() -> FleetOptions {
        FleetOptions {
            max_spaces: 16,
            idle_ttl: None,
            state_dir: None,
            fsync_every: 1,
            default_hyper: GpHyper::default(),
            max_rows_per_space: None,
            tier: FactorTier::Auto,
            shard_cap: crate::gp::DEFAULT_SHARD_CAP,
            blend_k: crate::gp::DEFAULT_BLEND_K,
        }
    }
}

/// The multi-space surrogate fleet (module docs).
struct Fleet {
    /// fingerprint -> space. The default space (bound by v2/v3 peers and
    /// by surrogate requests that arrive before any hello) lives under
    /// the daemon's own evaluate-plane space fingerprint. Read-locked on
    /// lookup so concurrent hellos to known spaces never queue; the
    /// write lock guards creation, recovery and eviction only.
    spaces: RwLock<HashMap<u64, Arc<SpaceState>>>,
    default_fp: u64,
    opts: FleetOptions,
}

/// Shared server state.
struct Shared {
    evaluator: Mutex<Box<dyn Evaluator + Send>>,
    space: SearchSpace,
    served: AtomicUsize,
    shutdown: AtomicBool,
    /// The surrogate fleet, when this daemon is a surrogate service
    /// (module docs).
    fleet: Option<Fleet>,
    /// Connection-id allocator (lease ownership / expiry).
    conns: AtomicU64,
    /// Observability: daemon-side lifecycle events (space create/evict,
    /// lease publish/expiry, served sync-factor wire cost) flow through
    /// this source once [`TargetServer::with_events`] attaches one.
    /// Write-once so connection handlers read it lock-free.
    events: OnceLock<EventSource>,
}

/// A running target daemon.
pub struct TargetServer {
    listener: TcpListener,
    shared: Arc<Shared>,
}

impl TargetServer {
    /// Bind to `addr` (e.g. "127.0.0.1:0" for an ephemeral port).
    pub fn bind(
        addr: &str,
        space: SearchSpace,
        evaluator: Box<dyn Evaluator + Send>,
    ) -> Result<TargetServer> {
        let listener = TcpListener::bind(addr).with_context(|| format!("binding {addr}"))?;
        Ok(TargetServer {
            listener,
            shared: Arc::new(Shared {
                evaluator: Mutex::new(evaluator),
                space,
                served: AtomicUsize::new(0),
                shutdown: AtomicBool::new(false),
                fleet: None,
                conns: AtomicU64::new(0),
                events: OnceLock::new(),
            }),
        })
    }

    /// Attach an observability event source (`surrogate-serve
    /// --events-addr` / `--events-file`): space lifecycle, lease churn
    /// and served sync-factor wire cost are emitted through it, and every
    /// *currently hosted* space's factor adopts a clone so its
    /// tell/drain/factor-size events flow too — call this after
    /// [`TargetServer::with_surrogate`] / [`TargetServer::with_fleet_options`]
    /// and before serving. Lazily created fleet spaces pick the source up
    /// at creation. Write-once: the first source wins.
    pub fn with_events(self, src: EventSource) -> TargetServer {
        if let Some(fleet) = &self.shared.fleet {
            for sp in fleet.spaces.read().unwrap().values() {
                sp.surrogate.set_event_source(src.clone());
            }
        }
        let _ = self.shared.events.set(src);
        self
    }

    /// Host `surrogate` as the authoritative shared factor next to the
    /// measurement daemon (module docs: the surrogate service). It
    /// becomes the fleet's *default* space — the one v2/v3 peers bind —
    /// keyed by the daemon space's fingerprint. Must be called before
    /// [`TargetServer::serve`]/[`TargetServer::spawn`]. Keep a clone of
    /// the handle to observe or reuse the factor after the daemon shuts
    /// down.
    pub fn with_surrogate(mut self, surrogate: SharedSurrogate) -> TargetServer {
        let shared = Arc::get_mut(&mut self.shared).expect("attach the surrogate before serving");
        let default_fp = shared.space.fingerprint();
        let dim = surrogate.dim().unwrap_or(0);
        let mut spaces = HashMap::new();
        spaces.insert(default_fp, Arc::new(SpaceState::new(default_fp, surrogate, dim)));
        shared.fleet = Some(Fleet {
            spaces: RwLock::new(spaces),
            default_fp,
            opts: FleetOptions::default(),
        });
        self
    }

    /// Configure the fleet (module docs): space cap, idle eviction,
    /// per-space durability. Call after [`TargetServer::with_surrogate`]
    /// and before serving. When `opts.state_dir` is set, every
    /// `space-<16 hex>/` namespace already on disk is recovered *now* —
    /// a restarted daemon boots with its whole fleet, not just the
    /// default space.
    pub fn with_fleet_options(mut self, opts: FleetOptions) -> Result<TargetServer> {
        let shared =
            Arc::get_mut(&mut self.shared).expect("configure the fleet before serving");
        let fleet = shared
            .fleet
            .as_mut()
            .expect("attach a surrogate (with_surrogate) before configuring the fleet");
        anyhow::ensure!(opts.max_spaces >= 1, "max_spaces must be at least 1");
        fleet.opts = opts;
        if let Some(root) = fleet.opts.state_dir.clone() {
            let spaces = fleet.spaces.get_mut().unwrap();
            for (fp, _dir) in crate::persist::list_space_dirs(&root)? {
                if spaces.len() >= fleet.opts.max_spaces {
                    eprintln!(
                        "tftune: fleet at --max-spaces {}; leaving space {fp:016x} on disk \
                         (it recovers on its next hello)",
                        fleet.opts.max_spaces
                    );
                    break;
                }
                if !spaces.contains_key(&fp) {
                    let sp = open_space(fp, 0, &fleet.opts)
                        .with_context(|| format!("recovering fleet space {fp:016x}"))?;
                    spaces.insert(fp, Arc::new(sp));
                }
            }
        }
        if fleet.opts.tier == FactorTier::Sharded {
            // Pinned sharded tier: convert every space already hosted —
            // the default space (attached exact by with_surrogate) and
            // anything recovery just rebuilt. Lazily created spaces are
            // converted by open_space.
            for sp in fleet.spaces.get_mut().unwrap().values() {
                sp.surrogate.convert_to_sharded(fleet.opts.shard_cap, fleet.opts.blend_k);
            }
        }
        Ok(self)
    }

    /// Bind a dedicated surrogate service: a daemon that hosts the
    /// authoritative factor (fresh, conditioned with `hyper`) and no
    /// measurement target — `evaluate` requests get a clean error.
    /// Returns the daemon and a local handle to the served factor.
    pub fn bind_surrogate_only(
        addr: &str,
        hyper: GpHyper,
    ) -> Result<(TargetServer, SharedSurrogate)> {
        TargetServer::bind_surrogate_with(addr, SharedSurrogate::new(hyper))
    }

    /// Like [`TargetServer::bind_surrogate_only`], but host an *existing*
    /// surrogate — e.g. one restored by
    /// [`persist::recover`](crate::persist::recover()) — instead of a
    /// fresh one. The served lease table starts empty either way: leases
    /// are liveness state scoped to live connections, so a restarted
    /// daemon forgets pre-crash leases and replicas re-publish on their
    /// next guard drop (see `gp::replica`).
    pub fn bind_surrogate_with(
        addr: &str,
        surrogate: SharedSurrogate,
    ) -> Result<(TargetServer, SharedSurrogate)> {
        let server = TargetServer::bind(
            addr,
            crate::space::threading_space(64, 1024, 64),
            Box::new(NoTarget),
        )?
        .with_surrogate(surrogate.clone());
        Ok((server, surrogate))
    }

    pub fn local_addr(&self) -> Result<std::net::SocketAddr> {
        Ok(self.listener.local_addr()?)
    }

    /// Serve until a shutdown request arrives. Blocking; one thread per
    /// connection (plus the idle-space sweeper when eviction is on).
    pub fn serve(self) -> Result<usize> {
        let sweeper = self
            .shared
            .fleet
            .as_ref()
            .and_then(|f| f.opts.idle_ttl)
            .map(|ttl| {
                let shared = Arc::clone(&self.shared);
                std::thread::spawn(move || sweep_idle_spaces(&shared, ttl))
            });
        let mut handles = Vec::new();
        for stream in self.listener.incoming() {
            if self.shared.shutdown.load(Ordering::SeqCst) {
                break;
            }
            let stream = stream?;
            // see RemoteEvaluator::connect — line-oriented protocol needs
            // nodelay on both ends to dodge Nagle/delayed-ACK stalls
            let _ = stream.set_nodelay(true);
            let shared = Arc::clone(&self.shared);
            handles.push(std::thread::spawn(move || handle_connection(stream, &shared)));
            if self.shared.shutdown.load(Ordering::SeqCst) {
                break;
            }
        }
        for h in handles {
            let _ = h.join();
        }
        if let Some(h) = sweeper {
            let _ = h.join();
        }
        Ok(self.shared.served.load(Ordering::SeqCst))
    }

    /// Spawn the server on a background thread; returns (addr, handle).
    pub fn spawn(self) -> Result<(std::net::SocketAddr, std::thread::JoinHandle<Result<usize>>)>
    {
        let addr = self.local_addr()?;
        let handle = std::thread::spawn(move || self.serve());
        Ok((addr, handle))
    }
}

/// Evaluator behind [`TargetServer::bind_surrogate_only`]: a surrogate
/// service with no measurement target.
struct NoTarget;

impl Evaluator for NoTarget {
    fn evaluate(&mut self, _config: &crate::space::Config) -> Result<f64> {
        anyhow::bail!("this daemon serves only the shared surrogate; no target is attached")
    }

    fn describe(&self) -> String {
        "surrogate-only".to_string()
    }
}

/// Serialise one response onto the shared connection writer.
fn write_response(writer: &Mutex<TcpStream>, resp: &Response, shared: &Shared) -> bool {
    let line = encode_response(resp, &shared.space);
    let mut w = writer.lock().unwrap();
    writeln!(w, "{line}").is_ok()
}

/// Build (or recover) the space for `fingerprint`. With a fleet state
/// dir the space journals into its own namespace and is recovered from
/// whatever a previous life left there; otherwise it starts fresh.
fn open_space(fingerprint: u64, dim: usize, opts: &FleetOptions) -> Result<SpaceState> {
    let sp = match &opts.state_dir {
        Some(root) => {
            let dir = crate::persist::space_dir(root, fingerprint);
            let recovered = crate::persist::recover(&dir, opts.default_hyper)?;
            let persist = crate::persist::attach(
                &recovered.surrogate,
                &dir,
                crate::persist::PersistOptions { fsync_every: opts.fsync_every },
            )?;
            let dim = recovered.surrogate.dim().unwrap_or(dim);
            let mut sp = SpaceState::new(fingerprint, recovered.surrogate, dim);
            sp.persist = Some(persist);
            sp
        }
        None => SpaceState::new(fingerprint, SharedSurrogate::new(opts.default_hyper), dim),
    };
    if opts.tier == FactorTier::Sharded {
        // Recovery always rebuilds the flat exact factor (snapshots are
        // tier-agnostic row stores); a pinned sharded fleet re-tiers the
        // space before any connection can bind it.
        sp.surrogate.convert_to_sharded(opts.shard_cap, opts.blend_k);
    }
    Ok(sp)
}

/// Bind an already-hosted space: dimension agreement, then `active`
/// incremented *while the caller still holds a map guard* — the sweeper
/// takes the write lock, so it can never evict a space between lookup
/// and bind.
fn bind_existing(
    sp: &Arc<SpaceState>,
    fingerprint: u64,
    dim: Option<usize>,
) -> Result<Arc<SpaceState>, String> {
    if let Some(d) = dim {
        // CAS, not load/store: two first-hellos racing under the shared
        // read lock must agree on a single served dimension.
        match sp.dim.compare_exchange(0, d, Ordering::SeqCst, Ordering::SeqCst) {
            Ok(_) => {}
            Err(have) if have == d => {}
            Err(have) => {
                return Err(format!(
                    "space {fingerprint:016x}: declared dimension {d} != served dimension \
                     {have} (mismatched client build, or a fingerprint collision)"
                ));
            }
        }
    }
    sp.active.fetch_add(1, Ordering::SeqCst);
    Ok(Arc::clone(sp))
}

/// Look up `fingerprint` in the fleet — lazily creating its space — and
/// bind it. Known spaces bind under the shared *read* lock (hello storms
/// to distinct spaces proceed in parallel); only a miss upgrades to the
/// write lock, double-checking the map after the upgrade. `Err` carries
/// the `hello-err` reason.
fn acquire_space(
    fleet: &Fleet,
    fingerprint: u64,
    dim: Option<usize>,
    events: Option<&EventSource>,
) -> Result<Arc<SpaceState>, String> {
    {
        let map = fleet.spaces.read().unwrap();
        if let Some(sp) = map.get(&fingerprint) {
            return bind_existing(sp, fingerprint, dim);
        }
    }
    let Some(d) = dim else {
        return Err(format!(
            "unknown space {fingerprint:016x}: a fingerprinted hello must declare \"dim\" \
             for the fleet to build its store"
        ));
    };
    let mut map = fleet.spaces.write().unwrap();
    if let Some(sp) = map.get(&fingerprint) {
        // Another hello created the space between our read miss and the
        // write lock.
        return bind_existing(sp, fingerprint, dim);
    }
    if map.len() >= fleet.opts.max_spaces {
        return Err(format!(
            "fleet is at --max-spaces {} and space {fingerprint:016x} is not hosted here",
            fleet.opts.max_spaces
        ));
    }
    let sp = match open_space(fingerprint, d, &fleet.opts) {
        Ok(sp) => sp,
        Err(e) => return Err(format!("space {fingerprint:016x}: {e:#}")),
    };
    if let Some(src) = events {
        sp.surrogate.set_event_source(src.clone());
        src.emit(Event::SpaceCreated { fingerprint, dim: d });
    }
    sp.active.fetch_add(1, Ordering::SeqCst);
    let sp = Arc::new(sp);
    map.insert(fingerprint, Arc::clone(&sp));
    Ok(sp)
}

/// Background sweeper: every fraction of the TTL, evict non-default
/// spaces that have had no bound connection for `ttl` — snapshotting
/// durable ones into their namespace first, so a later hello restores
/// them bit-identically (pinned in `tests/fleet_service.rs`).
fn sweep_idle_spaces(shared: &Shared, ttl: Duration) {
    let interval = (ttl / 4).clamp(Duration::from_millis(10), Duration::from_millis(250));
    let fleet = shared.fleet.as_ref().expect("sweeper runs only with a fleet");
    while !shared.shutdown.load(Ordering::SeqCst) {
        std::thread::sleep(interval);
        let mut evicted = Vec::new();
        {
            // Write lock: eviction must be atomic with respect to
            // acquire_space's read-locked bind (a space still in the map
            // cannot gain a binder while we remove it).
            let mut map = fleet.spaces.write().unwrap();
            let dead: Vec<u64> = map
                .iter()
                .filter(|(fp, sp)| {
                    **fp != fleet.default_fp
                        && sp.active.load(Ordering::SeqCst) == 0
                        && sp.last_release.lock().unwrap().elapsed() >= ttl
                })
                .map(|(fp, _)| *fp)
                .collect();
            for fp in dead {
                if let Some(sp) = map.remove(&fp) {
                    evicted.push(sp);
                }
            }
        }
        // Snapshot off the map lock: new hellos for *other* spaces are
        // not blocked on eviction I/O, and nobody can re-bind an evicted
        // space (it is out of the map; a re-hello recovers it from disk).
        for sp in evicted {
            if let Some(src) = shared.events.get() {
                src.emit(Event::SpaceEvicted {
                    fingerprint: sp.fingerprint,
                    rows: sp.surrogate.len(),
                });
            }
            match &sp.persist {
                Some(p) => match p.snapshot(&sp.surrogate) {
                    Ok(seq) => eprintln!(
                        "tftune: evicted idle space {:016x} (snapshot seq {seq})",
                        sp.fingerprint
                    ),
                    Err(e) => eprintln!(
                        "tftune: evicting space {:016x}: snapshot failed ({e}); the WAL \
                         alone still recovers it",
                        sp.fingerprint
                    ),
                },
                None => eprintln!(
                    "tftune: evicted idle space {:016x} ({} observation(s) discarded — \
                     run with --state-dir to make the fleet durable)",
                    sp.fingerprint,
                    sp.surrogate.len()
                ),
            }
        }
    }
}

/// Per-connection surrogate-plane state: which fleet space this
/// connection conditions.
struct ConnCtx {
    id: u64,
    space: Option<Arc<SpaceState>>,
    /// Daemon event source (cloned from [`Shared::events`] at accept
    /// time) — lease expiry on release/disconnect reports through it.
    events: Option<EventSource>,
}

impl ConnCtx {
    /// The space this connection is bound to, binding the *default*
    /// space on first use — the contract every pre-v4 peer (and any
    /// surrogate request arriving before a hello) relies on. `None` when
    /// this daemon hosts no fleet.
    fn space(&mut self, shared: &Shared) -> Option<Arc<SpaceState>> {
        if self.space.is_none() {
            let fleet = shared.fleet.as_ref()?;
            let map = fleet.spaces.read().unwrap();
            let sp = map.get(&fleet.default_fp).expect("the default space is never evicted");
            sp.active.fetch_add(1, Ordering::SeqCst);
            self.space = Some(Arc::clone(sp));
        }
        self.space.clone()
    }

    /// Rebind to `sp` (hello): the old space loses this connection's
    /// leases and its idle clock starts if we were its last binder.
    fn bind(&mut self, sp: Arc<SpaceState>) {
        self.release();
        self.space = Some(sp);
    }

    /// Unbind (disconnect or re-hello): lease expiry + idle bookkeeping.
    fn release(&mut self) {
        if let Some(sp) = self.space.take() {
            let expired = {
                let mut table = sp.leases.lock().unwrap();
                let before = table.entries.len();
                table.entries.retain(|e| e.conn != self.id);
                before - table.entries.len()
            };
            if expired > 0 {
                if let Some(src) = &self.events {
                    src.emit(Event::LeaseExpired { leases: expired });
                }
            }
            if sp.active.fetch_sub(1, Ordering::SeqCst) == 1 {
                *sp.last_release.lock().unwrap() = Instant::now();
            }
        }
    }
}

/// Row-cap policy (`--max-rows-per-space`, module docs). `None` lets the
/// tell proceed — converting the space to the sharded tier first when
/// the cap is reached under [`FactorTier::Auto`]; `Some(reason)` refuses
/// it ([`FactorTier::Exact`] at the cap). Counts *total* observations
/// (queued tells included), so a fire-and-forget storm cannot overshoot
/// the cap by the queue depth.
fn enforce_row_cap(opts: &FleetOptions, sp: &SpaceState) -> Option<String> {
    let cap = opts.max_rows_per_space?;
    if sp.surrogate.total_observations() < cap {
        return None;
    }
    match opts.tier {
        FactorTier::Exact => Some(format!(
            "space {:016x} is at --max-rows-per-space {cap} and the factor tier is pinned \
             exact; raise the cap or serve --surrogate sharded",
            sp.fingerprint
        )),
        FactorTier::Auto | FactorTier::Sharded => {
            if !sp.surrogate.is_sharded() {
                sp.surrogate.convert_to_sharded(opts.shard_cap, opts.blend_k);
                eprintln!(
                    "tftune: space {:016x} reached {cap} row(s); factor converted to the \
                     sharded tier (shard cap {}, blend {})",
                    sp.fingerprint, opts.shard_cap, opts.blend_k
                );
            }
            None
        }
    }
}

/// Serve one surrogate-plane request (module docs: the surrogate
/// service). Returns false when the connection writer is gone.
fn handle_surrogate_request(
    req: SurrogateRequest,
    shared: &Shared,
    conn: &mut ConnCtx,
    writer: &Mutex<TcpStream>,
) -> bool {
    const NO_FACTOR: &str = "this daemon hosts no shared surrogate (start one with \
                             `surrogate-serve` or attach it via TargetServer::with_surrogate)";
    let no_factor = || SurrogateResponse::Error { message: NO_FACTOR.to_string() };
    // Observability (clock read only when a source is live): a served
    // `sync-factor` reports rows exported + raw response bytes + elapsed
    // nanos, mirroring what the requesting replica attributes to the wire.
    let events = shared.events.get().filter(|s| s.enabled());
    let t0 = events.map(|_| Instant::now());
    let mut sync_rows: Option<usize> = None;
    let resp = match req {
        // The handshake answers on any daemon — it reports the
        // *negotiated* version, min(client, server), so an old peer
        // keeps speaking its own protocol (single-objective tells)
        // against a newer daemon instead of being refused. A
        // fingerprinted hello (v4) additionally binds this connection to
        // its fleet space, or gets a typed refusal.
        SurrogateRequest::Hello { version, fingerprint, dim } => {
            let negotiated = version.min(PROTOCOL_VERSION);
            match (&shared.fleet, fingerprint) {
                (_, None) => SurrogateResponse::HelloOk { version: negotiated },
                (None, Some(_)) => {
                    SurrogateResponse::HelloErr { reason: NO_FACTOR.to_string() }
                }
                (Some(fleet), Some(fp)) => match acquire_space(fleet, fp, dim, events) {
                    Ok(sp) => {
                        conn.bind(sp);
                        SurrogateResponse::HelloOk { version: negotiated }
                    }
                    Err(reason) => SurrogateResponse::HelloErr { reason },
                },
            }
        }
        SurrogateRequest::TellObs { x, y, ys } => match conn.space(shared) {
            Some(sp) => {
                let opts = &shared.fleet.as_ref().expect("a bound space implies a fleet").opts;
                if let Some(message) = enforce_row_cap(opts, &sp) {
                    // A tell is fire-and-forget, so a refusal cannot be
                    // paired positionally: write one typed error line and
                    // close the connection (return false). The teller's
                    // next round trip surfaces the error and it redials.
                    let line =
                        encode_surrogate_response(&SurrogateResponse::Error { message });
                    let mut w = writer.lock().unwrap();
                    let _ = writeln!(w, "{line}");
                    return false;
                }
                // Fire-and-forget: queue into this space's factor
                // (enqueue order across connections = arrival order here)
                // and send no response, so tells never stall the teller.
                // Secondary objective columns (v3) ride into the store
                // with the row; a v2 teller simply contributes
                // single-objective rows. A wrong-dimension row is dropped
                // by the store's own drain guard, never corrupting the
                // space — fingerprinted hellos make that a can't-happen
                // for well-built clients.
                let mut all = Vec::with_capacity(1 + ys.len());
                all.push(y);
                all.extend(ys);
                sp.surrogate.tell_multi(x, all);
                return true;
            }
            None => no_factor(),
        },
        SurrogateRequest::SyncFactor { from_n, max_rows, quantise } => {
            match conn.space(shared) {
                Some(sp) => match sp.surrogate.export_delta(from_n) {
                    Some(mut d) => {
                        // Chunked catch-up (v4): bound the delta to
                        // `max_rows` rows, truncating rows/extras and the
                        // packed factor suffix consistently and rewriting
                        // `total_n` to the chunk end (the import contract
                        // checks row count against it). `pending` tells
                        // the replica how far behind it still is.
                        let mut pending = 0;
                        if let Some(k) = max_rows {
                            let k = k.max(1); // a 0-row chunk would never progress
                            if d.rows.len() > k {
                                pending = d.rows.len() - k;
                                d.rows.truncate(k);
                                if !d.extras.is_empty() {
                                    d.extras.truncate(k);
                                }
                                d.total_n = from_n + k;
                                if let Some(f) = &mut d.factor {
                                    f.truncate(packed_len(from_n + k) - packed_len(from_n));
                                }
                            }
                        }
                        if pending == 0 {
                            // Leases ride only on the final chunk: the
                            // requester conditions its own in-flight
                            // trials itself, and every import replaces
                            // the ambient lease set wholesale anyway.
                            let table = sp.leases.lock().unwrap();
                            d.leases = table
                                .entries
                                .iter()
                                .filter(|e| e.conn != conn.id)
                                .flat_map(|e| e.points.iter().cloned())
                                .collect();
                        }
                        let quantised = quantise && d.factor.is_some();
                        sync_rows = Some(d.rows.len());
                        SurrogateResponse::FactorDelta { delta: d, pending, quantised }
                    }
                    None => SurrogateResponse::Error {
                        message: format!(
                            "replica claims {from_n} rows, ahead of the served factor"
                        ),
                    },
                },
                None => no_factor(),
            }
        }
        SurrogateRequest::AskLease { points } => match conn.space(shared) {
            Some(sp) => {
                let published = points.len();
                let id = {
                    let mut table = sp.leases.lock().unwrap();
                    table.next_id += 1;
                    let id = table.next_id;
                    table.entries.push(LeaseEntry { id, conn: conn.id, points });
                    id
                };
                if let Some(src) = events {
                    src.emit(Event::LeasePublished { id, points: published });
                }
                SurrogateResponse::Lease { id }
            }
            None => no_factor(),
        },
        SurrogateRequest::RetractLease { id } => match conn.space(shared) {
            Some(sp) => {
                let expired = {
                    let mut table = sp.leases.lock().unwrap();
                    let before = table.entries.len();
                    table.entries.retain(|e| e.id != id || e.conn != conn.id);
                    before - table.entries.len()
                };
                if expired > 0 {
                    if let Some(src) = events {
                        src.emit(Event::LeaseExpired { leases: expired });
                    }
                }
                SurrogateResponse::LeaseOk { id }
            }
            None => no_factor(),
        },
        SurrogateRequest::SetHyper { hyper } => match conn.space(shared) {
            Some(sp) => {
                sp.surrogate.set_hyper(hyper);
                SurrogateResponse::HyperOk
            }
            None => no_factor(),
        },
    };
    let line = encode_surrogate_response(&resp);
    if let (Some(src), Some(t0), Some(rows)) = (events, t0, sync_rows) {
        // +1: the newline `writeln!` appends — matches the byte count the
        // replica reads off the wire.
        src.emit(Event::SyncFactor {
            rows,
            bytes: line.len() + 1,
            ns: t0.elapsed().as_nanos() as u64,
        });
    }
    let mut w = writer.lock().unwrap();
    writeln!(w, "{line}").is_ok()
}

/// Run one measurement on the shared system under test.
fn evaluate_response(
    shared: &Shared,
    config: crate::space::Config,
    trial: Option<u64>,
) -> Response {
    let t0 = std::time::Instant::now();
    match shared.evaluator.lock().unwrap().evaluate(&config) {
        Ok(value) => {
            shared.served.fetch_add(1, Ordering::SeqCst);
            Response::Result { value, cost_s: t0.elapsed().as_secs_f64(), config, trial }
        }
        Err(e) => Response::Error { message: format!("evaluation failed: {e}"), trial },
    }
}

fn handle_connection(stream: TcpStream, shared: &Shared) {
    let writer = match stream.try_clone() {
        Ok(w) => Mutex::new(w),
        Err(_) => return,
    };
    // Lease scope: this connection's published constant-liar points live
    // exactly as long as the connection (expiry on disconnect). The
    // surrogate plane additionally tracks which fleet space the
    // connection is bound to (default space until a fingerprinted hello).
    let mut conn = ConnCtx {
        id: shared.conns.fetch_add(1, Ordering::SeqCst),
        space: None,
        events: shared.events.get().cloned(),
    };
    let reader = BufReader::new(stream);
    // Scoped workers let every in-flight evaluate borrow `shared` and the
    // connection writer: the reader keeps pulling pipelined requests while
    // measurements run, and responses go out tagged in completion order.
    std::thread::scope(|scope| {
        for line in reader.lines() {
            let line = match line {
                Ok(l) => l,
                Err(_) => break,
            };
            if line.trim().is_empty() {
                continue;
            }
            match decode_request(&line, &shared.space) {
                Err(e) => {
                    // Not an evaluate-plane message: try the surrogate
                    // plane before reporting a decode error.
                    match decode_surrogate_request(&line) {
                        Ok(sreq) => {
                            if !handle_surrogate_request(sreq, shared, &mut conn, &writer) {
                                break;
                            }
                        }
                        Err(_) => {
                            if !write_response(
                                &writer,
                                &Response::Error { message: e, trial: None },
                                shared,
                            ) {
                                break;
                            }
                        }
                    }
                }
                Ok(Request::Describe) => {
                    let desc = shared.evaluator.lock().unwrap().describe();
                    if !write_response(
                        &writer,
                        &Response::Target { description: desc },
                        shared,
                    ) {
                        break;
                    }
                }
                // Untagged (legacy) evaluate: answered inline so responses
                // stay in request order, exactly like the pre-pipelining
                // server — an in-order client pairs them positionally.
                Ok(Request::Evaluate { config, trial: None }) => {
                    let resp = evaluate_response(shared, config, None);
                    if !write_response(&writer, &resp, shared) {
                        break;
                    }
                }
                // Tagged evaluate: measured on a scoped worker and written
                // in completion order; the echoed trial id pairs it.
                Ok(Request::Evaluate { config, trial: trial @ Some(_) }) => {
                    let writer = &writer;
                    scope.spawn(move || {
                        let resp = evaluate_response(shared, config, trial);
                        write_response(writer, &resp, shared);
                    });
                }
                Ok(Request::Shutdown) => {
                    shared.shutdown.store(true, Ordering::SeqCst);
                    write_response(&writer, &Response::Bye, shared);
                    // poke the accept loop so serve() notices the flag
                    if let Ok(addr) = writer.lock().unwrap().local_addr() {
                        let _ = TcpStream::connect(addr);
                    }
                    break;
                }
            }
        }
        // scope joins any still-running evaluations before the connection
        // closes, so their responses are flushed first.
    });
    // Lease expiry on disconnect: a replica that died mid-batch (or never
    // retracted) stops conditioning its siblings' models right here. The
    // release also starts the bound space's idle clock when this was its
    // last connection.
    conn.release();
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::evaluator::SimEvaluator;
    use crate::sim::ModelId;
    use std::io::{BufRead, BufReader, Write};

    fn start() -> (std::net::SocketAddr, std::thread::JoinHandle<Result<usize>>, SearchSpace)
    {
        let model = ModelId::NcfFp32;
        let space = model.space();
        let server = TargetServer::bind(
            "127.0.0.1:0",
            space.clone(),
            Box::new(SimEvaluator::new(model, 9)),
        )
        .unwrap();
        let (addr, handle) = server.spawn().unwrap();
        (addr, handle, space)
    }

    fn send(addr: std::net::SocketAddr, lines: &[String]) -> Vec<String> {
        let mut s = TcpStream::connect(addr).unwrap();
        for l in lines {
            writeln!(s, "{l}").unwrap();
        }
        let reader = BufReader::new(s.try_clone().unwrap());
        let mut out = Vec::new();
        for line in reader.lines().take(lines.len()) {
            out.push(line.unwrap());
        }
        drop(s);
        out
    }

    #[test]
    fn describe_evaluate_shutdown() {
        let (addr, handle, space) = start();
        let resp = send(
            addr,
            &[
                proto::encode_request(&Request::Describe, &space),
                proto::encode_request(
                    &Request::Evaluate { config: vec![1, 8, 128, 0, 8], trial: None },
                    &space,
                ),
            ],
        );
        let r0 = proto::decode_response(&resp[0], &space).unwrap();
        assert!(matches!(r0, Response::Target { .. }));
        match proto::decode_response(&resp[1], &space).unwrap() {
            Response::Result { value, config, trial, .. } => {
                assert!(value > 0.0);
                assert_eq!(config, vec![1, 8, 128, 0, 8]);
                assert_eq!(trial, None);
            }
            other => panic!("unexpected {other:?}"),
        }
        // shutdown
        let _ = send(addr, &[proto::encode_request(&Request::Shutdown, &space)]);
        let served = handle.join().unwrap().unwrap();
        assert_eq!(served, 1);
    }

    #[test]
    fn pipelined_trials_come_back_tagged() {
        let (addr, handle, space) = start();
        // Fire four tagged evaluate requests before reading any response.
        let reqs: Vec<String> = (0..4u64)
            .map(|id| {
                proto::encode_request(
                    &Request::Evaluate {
                        config: vec![1, 8, 128, 0, 8 + id as i64],
                        trial: Some(id),
                    },
                    &space,
                )
            })
            .collect();
        let resp = send(addr, &reqs);
        assert_eq!(resp.len(), 4);
        let mut ids = Vec::new();
        for line in &resp {
            match proto::decode_response(line, &space).unwrap() {
                Response::Result { value, cost_s, trial, .. } => {
                    assert!(value > 0.0);
                    assert!(cost_s >= 0.0);
                    ids.push(trial.expect("tagged request must get a tagged response"));
                }
                other => panic!("unexpected {other:?}"),
            }
        }
        // Completion order may differ from issue order; the id *set* must
        // match exactly.
        ids.sort_unstable();
        assert_eq!(ids, vec![0, 1, 2, 3]);
        let _ = send(addr, &[proto::encode_request(&Request::Shutdown, &space)]);
        let served = handle.join().unwrap().unwrap();
        assert_eq!(served, 4);
    }

    #[test]
    fn surrogate_plane_tell_sync_lease_over_tcp() {
        let (server, factor) =
            TargetServer::bind_surrogate_only("127.0.0.1:0", crate::gp::GpHyper::default())
                .unwrap();
        let (addr, handle) = server.spawn().unwrap();
        let space = crate::space::threading_space(64, 1024, 64);

        let mut s = TcpStream::connect(addr).unwrap();
        let mut reader = BufReader::new(s.try_clone().unwrap());
        fn roundtrip(
            s: &mut TcpStream,
            reader: &mut BufReader<TcpStream>,
            req: &SurrogateRequest,
        ) -> SurrogateResponse {
            writeln!(s, "{}", proto::encode_surrogate_request(req)).unwrap();
            let mut line = String::new();
            reader.read_line(&mut line).unwrap();
            proto::decode_surrogate_response(line.trim_end()).unwrap()
        }

        // Handshake negotiates min(client, server): a v2 client is
        // answered at v2, a current client at the server's version.
        match roundtrip(&mut s, &mut reader, &SurrogateRequest::Hello { version: 2, fingerprint: None, dim: None }) {
            SurrogateResponse::HelloOk { version } => assert_eq!(version, 2),
            other => panic!("unexpected {other:?}"),
        }
        match roundtrip(
            &mut s,
            &mut reader,
            &SurrogateRequest::Hello { version: PROTOCOL_VERSION, fingerprint: None, dim: None },
        ) {
            SurrogateResponse::HelloOk { version } => assert_eq!(version, PROTOCOL_VERSION),
            other => panic!("unexpected {other:?}"),
        }
        // Fire-and-forget tells (no response), then a sync that must see
        // both of them in arrival order.
        for (x, y) in [(vec![0.25, 0.5], 1.0), (vec![0.75, 0.5], 2.0)] {
            writeln!(
                s,
                "{}",
                proto::encode_surrogate_request(&SurrogateRequest::TellObs {
                    x,
                    y,
                    ys: Vec::new()
                })
            )
            .unwrap();
        }
        match roundtrip(&mut s, &mut reader, &SurrogateRequest::SyncFactor { from_n: 0, max_rows: None, quantise: false }) {
            SurrogateResponse::FactorDelta { delta: d, .. } => {
                assert_eq!(d.total_n, 2);
                assert_eq!(d.rows.len(), 2);
                assert_eq!(d.rows[0].1, 1.0);
                assert_eq!(d.rows[1].1, 2.0);
                assert!(d.factor.is_some(), "eager prefix factor rides along");
                assert!(d.leases.is_empty(), "own leases are never served back");
            }
            other => panic!("unexpected {other:?}"),
        }
        assert_eq!(factor.len(), 2, "tells landed in the hosted factor");

        // A lease from this connection is invisible to it but visible to
        // a second connection — until this connection closes.
        match roundtrip(
            &mut s,
            &mut reader,
            &SurrogateRequest::AskLease { points: vec![(vec![0.1, 0.1], 0.0)] },
        ) {
            SurrogateResponse::Lease { .. } => {}
            other => panic!("unexpected {other:?}"),
        }
        match roundtrip(&mut s, &mut reader, &SurrogateRequest::SyncFactor { from_n: 2, max_rows: None, quantise: false }) {
            SurrogateResponse::FactorDelta { delta: d, .. } => assert!(d.leases.is_empty()),
            other => panic!("unexpected {other:?}"),
        }
        let mut s2 = TcpStream::connect(addr).unwrap();
        let mut reader2 = BufReader::new(s2.try_clone().unwrap());
        match roundtrip(&mut s2, &mut reader2, &SurrogateRequest::SyncFactor { from_n: 0, max_rows: None, quantise: false }) {
            SurrogateResponse::FactorDelta { delta: d, .. } => {
                assert_eq!(d.leases, vec![(vec![0.1, 0.1], 0.0)]);
            }
            other => panic!("unexpected {other:?}"),
        }
        // Both halves of the first connection must close for the server's
        // reader to see EOF.
        drop(s);
        drop(reader);
        // Lease expiry on disconnect (poll: the server notices EOF async).
        let mut expired = false;
        for _ in 0..200 {
            match roundtrip(&mut s2, &mut reader2, &SurrogateRequest::SyncFactor { from_n: 2, max_rows: None, quantise: false })
            {
                SurrogateResponse::FactorDelta { delta: d, .. } => {
                    if d.leases.is_empty() {
                        expired = true;
                        break;
                    }
                }
                other => panic!("unexpected {other:?}"),
            }
            std::thread::sleep(std::time::Duration::from_millis(5));
        }
        assert!(expired, "lease survived its connection");

        // A surrogate-only daemon refuses measurements cleanly.
        writeln!(
            s2,
            "{}",
            proto::encode_request(
                &Request::Evaluate { config: vec![1, 8, 128, 0, 8], trial: None },
                &space,
            )
        )
        .unwrap();
        let mut line = String::new();
        reader2.read_line(&mut line).unwrap();
        match proto::decode_response(line.trim_end(), &space).unwrap() {
            Response::Error { message, .. } => {
                assert!(message.contains("no target"), "{message}")
            }
            other => panic!("unexpected {other:?}"),
        }

        let _ = send(addr, &[proto::encode_request(&Request::Shutdown, &space)]);
        let _ = handle.join();
    }

    #[test]
    fn measurement_daemon_without_surrogate_refuses_the_plane() {
        let (addr, handle, space) = start();
        let mut s = TcpStream::connect(addr).unwrap();
        writeln!(
            s,
            "{}",
            proto::encode_surrogate_request(&SurrogateRequest::SyncFactor { from_n: 0, max_rows: None, quantise: false })
        )
        .unwrap();
        let mut reader = BufReader::new(s.try_clone().unwrap());
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        match proto::decode_surrogate_response(line.trim_end()).unwrap() {
            SurrogateResponse::Error { message } => {
                assert!(message.contains("hosts no shared surrogate"), "{message}")
            }
            other => panic!("unexpected {other:?}"),
        }
        drop(s);
        let _ = send(addr, &[proto::encode_request(&Request::Shutdown, &space)]);
        let _ = handle.join();
    }

    #[test]
    fn garbage_request_gets_error_response() {
        let (addr, handle, space) = start();
        let resp = send(addr, &["this is not json".to_string()]);
        match proto::decode_response(&resp[0], &space).unwrap() {
            Response::Error { .. } => {}
            other => panic!("unexpected {other:?}"),
        }
        let _ = send(addr, &[proto::encode_request(&Request::Shutdown, &space)]);
        let _ = handle.join();
    }
}
