//! Wire protocol for the host/target split (paper Fig. 4): JSON-lines
//! over TCP. One request per line, one response per line — but responses
//! to `evaluate` may arrive *out of order*: requests carry an optional
//! trial id that the target echoes back, so a host can pipeline several
//! in-flight trials on one connection and match completions by id.
//!
//! Requests and responses:
//!
//! ```text
//! -> {"type":"describe"}
//! -> {"type":"evaluate","config":{"<param>":<int>,...}[,"trial":<id>]}
//! -> {"type":"shutdown"}
//! <- {"type":"target","description":"..."}
//! <- {"type":"result","value":<f64>,"cost_s":<f64>,"config":{...}[,"trial":<id>]}
//! <- {"type":"error","message":"..."[,"trial":<id>]}
//! <- {"type":"bye"}
//! ```
//!
//! Untagged `evaluate` requests (the pre-ask/tell protocol) remain valid:
//! their responses simply omit the trial id and are answered in order.

use crate::space::{Config, SearchSpace};
use crate::util::json::{parse, Json};

/// Parsed request.
#[derive(Debug, Clone, PartialEq)]
pub enum Request {
    Describe,
    Evaluate { config: Config, trial: Option<u64> },
    Shutdown,
}

/// Parsed response.
#[derive(Debug, Clone, PartialEq)]
pub enum Response {
    Target { description: String },
    Result { value: f64, cost_s: f64, config: Config, trial: Option<u64> },
    Error { message: String, trial: Option<u64> },
    Bye,
}

fn push_trial(pairs: &mut Vec<(&str, Json)>, trial: &Option<u64>) {
    if let Some(id) = trial {
        pairs.push(("trial", Json::from(*id as i64)));
    }
}

fn get_trial(j: &Json) -> Option<u64> {
    j.get("trial").and_then(Json::as_i64).and_then(|t| u64::try_from(t).ok())
}

pub fn encode_request(req: &Request, space: &SearchSpace) -> String {
    match req {
        Request::Describe => Json::obj(vec![("type", "describe".into())]).to_string(),
        Request::Evaluate { config, trial } => {
            let mut pairs = vec![
                ("type", "evaluate".into()),
                ("config", space.config_to_json(config)),
            ];
            push_trial(&mut pairs, trial);
            Json::obj(pairs).to_string()
        }
        Request::Shutdown => Json::obj(vec![("type", "shutdown".into())]).to_string(),
    }
}

pub fn decode_request(line: &str, space: &SearchSpace) -> Result<Request, String> {
    let j = parse(line).map_err(|e| e.to_string())?;
    match j.get("type").and_then(Json::as_str) {
        Some("describe") => Ok(Request::Describe),
        Some("evaluate") => {
            let config = space.config_from_json(j.req("config").map_err(|e| e.to_string())?)?;
            Ok(Request::Evaluate { config, trial: get_trial(&j) })
        }
        Some("shutdown") => Ok(Request::Shutdown),
        other => Err(format!("unknown request type {other:?}")),
    }
}

pub fn encode_response(resp: &Response, space: &SearchSpace) -> String {
    match resp {
        Response::Target { description } => Json::obj(vec![
            ("type", "target".into()),
            ("description", description.as_str().into()),
        ])
        .to_string(),
        Response::Result { value, cost_s, config, trial } => {
            let mut pairs = vec![
                ("type", "result".into()),
                ("value", (*value).into()),
                ("cost_s", (*cost_s).into()),
                ("config", space.config_to_json(config)),
            ];
            push_trial(&mut pairs, trial);
            Json::obj(pairs).to_string()
        }
        Response::Error { message, trial } => {
            let mut pairs = vec![
                ("type", "error".into()),
                ("message", message.as_str().into()),
            ];
            push_trial(&mut pairs, trial);
            Json::obj(pairs).to_string()
        }
        Response::Bye => Json::obj(vec![("type", "bye".into())]).to_string(),
    }
}

pub fn decode_response(line: &str, space: &SearchSpace) -> Result<Response, String> {
    let j = parse(line).map_err(|e| e.to_string())?;
    match j.get("type").and_then(Json::as_str) {
        Some("target") => Ok(Response::Target {
            description: j
                .get("description")
                .and_then(Json::as_str)
                .unwrap_or("")
                .to_string(),
        }),
        Some("result") => {
            let value = j
                .get("value")
                .and_then(Json::as_f64)
                .ok_or("result missing value")?;
            let cost_s = j.get("cost_s").and_then(Json::as_f64).unwrap_or(0.0);
            let config = space.config_from_json(j.req("config").map_err(|e| e.to_string())?)?;
            Ok(Response::Result { value, cost_s, config, trial: get_trial(&j) })
        }
        Some("error") => Ok(Response::Error {
            message: j.get("message").and_then(Json::as_str).unwrap_or("").to_string(),
            trial: get_trial(&j),
        }),
        Some("bye") => Ok(Response::Bye),
        other => Err(format!("unknown response type {other:?}")),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::space::threading_space;
    use crate::util::prop;

    fn space() -> SearchSpace {
        threading_space(64, 1024, 64)
    }

    #[test]
    fn request_round_trip() {
        let s = space();
        for req in [
            Request::Describe,
            Request::Evaluate { config: vec![2, 10, 128, 30, 20], trial: None },
            Request::Evaluate { config: vec![2, 10, 128, 30, 20], trial: Some(7) },
            Request::Shutdown,
        ] {
            let line = encode_request(&req, &s);
            assert_eq!(decode_request(&line, &s).unwrap(), req, "line: {line}");
        }
    }

    #[test]
    fn response_round_trip() {
        let s = space();
        for resp in [
            Response::Target { description: "sim:X".into() },
            Response::Result {
                value: 123.5,
                cost_s: 0.25,
                config: vec![1, 1, 64, 0, 1],
                trial: None,
            },
            Response::Result {
                value: 9.0,
                cost_s: 0.0,
                config: vec![1, 1, 64, 0, 1],
                trial: Some(41),
            },
            Response::Error { message: "boom \"quoted\"".into(), trial: Some(3) },
            Response::Error { message: "untagged".into(), trial: None },
            Response::Bye,
        ] {
            let line = encode_response(&resp, &s);
            assert_eq!(decode_response(&line, &s).unwrap(), resp, "line: {line}");
        }
    }

    #[test]
    fn legacy_untagged_result_decodes() {
        // A pre-ask/tell peer sends results without trial/cost fields.
        let s = space();
        let cfg = vec![1, 1, 64, 0, 1];
        let line = format!(
            r#"{{"type":"result","value":5.5,"config":{}}}"#,
            s.config_to_json(&cfg)
        );
        match decode_response(&line, &s).unwrap() {
            Response::Result { value, cost_s, trial, .. } => {
                assert_eq!(value, 5.5);
                assert_eq!(cost_s, 0.0);
                assert_eq!(trial, None);
            }
            other => panic!("unexpected {other:?}"),
        }
    }

    #[test]
    fn rejects_garbage() {
        let s = space();
        assert!(decode_request("not json", &s).is_err());
        assert!(decode_request(r#"{"type":"nope"}"#, &s).is_err());
        assert!(decode_response(r#"{"type":"result"}"#, &s).is_err());
    }

    #[test]
    fn prop_evaluate_round_trip_any_config_and_id() {
        let s = space();
        prop::check("proto evaluate round trip", 100, |rng| {
            let config = s.random(rng);
            let trial = if rng.bool(0.5) { Some(rng.next_u64() >> 12) } else { None };
            let req = Request::Evaluate { config, trial };
            let line = encode_request(&req, &s);
            assert_eq!(decode_request(&line, &s).unwrap(), req);
        });
    }
}
