//! Wire protocol for the host/target split (paper Fig. 4): JSON-lines
//! over TCP. One request per line, one response per line — but responses
//! to `evaluate` may arrive *out of order*: requests carry an optional
//! trial id that the target echoes back, so a host can pipeline several
//! in-flight trials on one connection and match completions by id.
//!
//! Requests and responses:
//!
//! ```text
//! -> {"type":"describe"}
//! -> {"type":"evaluate","config":{"<param>":<int>,...}[,"trial":<id>]}
//! -> {"type":"shutdown"}
//! <- {"type":"target","description":"..."}
//! <- {"type":"result","value":<f64>,"cost_s":<f64>,"config":{...}[,"trial":<id>]}
//! <- {"type":"error","message":"..."[,"trial":<id>]}
//! <- {"type":"bye"}
//! ```
//!
//! Untagged `evaluate` requests (the pre-ask/tell protocol) remain valid:
//! their responses simply omit the trial id and are answered in order.
//!
//! # The surrogate plane (protocol v2)
//!
//! [`PROTOCOL_VERSION`] 2 adds a second message plane on the same
//! JSON-lines connection: the **surrogate service**, which replicates one
//! shared GP factor across tuner processes (see `gp::replica` and
//! `ARCHITECTURE.md` §"The surrogate service"). Its messages are
//! space-free (inputs are unit-cube coordinates), so
//! [`encode_surrogate_request`]/[`decode_surrogate_response`] take no
//! `SearchSpace`:
//!
//! ```text
//! -> {"type":"hello","version":2}                      protocol handshake
//! -> {"type":"tell-obs","x":[...],"y":<f64>}           fire-and-forget observation
//! -> {"type":"sync-factor","from_n":<n>}               catch-up request
//! -> {"type":"ask-lease","points":[{"x":[...],"lie":<f64>},...]}
//! -> {"type":"retract-lease","id":<id>}
//! -> {"type":"set-hyper","hyper":{...}}
//! <- {"type":"hello-ok","version":2}
//! <- {"type":"factor-delta","from_n":..,"total_n":..,"hyper":{...},
//!     "rows":[{"x":[...],"y":..},...],"factor":[...]|null,
//!     "leases":[{"x":[...],"lie":..},...]}
//! <- {"type":"lease","id":<id>}
//! <- {"type":"lease-ok","id":<id>}
//! <- {"type":"hyper-ok"}
//! <- {"type":"error","message":"..."}                  shared with the evaluate plane
//! ```
//!
//! `tell-obs` gets **no** response on success — tells must never block on
//! the service. Leases are scoped to the connection that asked them: the
//! daemon drops a connection's leases when it closes, which is how a
//! crashed tuner's constant-liar fantasies expire. f64 values survive the
//! wire bit-exactly (shortest-round-trip encode, correctly-rounded parse).
//!
//! # Multi-objective columns (protocol v3)
//!
//! [`PROTOCOL_VERSION`] 3 lets observations carry K objective columns:
//! `tell-obs` and each `factor-delta` row gain an optional `"ys"` array
//! holding the *secondary* columns (the primary stays in `"y"`), e.g.
//!
//! ```text
//! -> {"type":"tell-obs","x":[...],"y":<f64>,"ys":[<f64>|null,...]}
//! <- ... "rows":[{"x":[...],"y":..,"ys":[..]},...] ...
//! ```
//!
//! `null` inside `"ys"` marks a declared column that trial could not
//! measure (NaN in memory — NaN is not representable in JSON); consumers
//! degrade that row to the columns it does carry. The handshake
//! negotiates down: `hello-ok` answers `min(client, server)` versions, so
//! a v2 peer keeps working single-objective — a v2 sender simply never
//! writes `"ys"`, and a v2 receiver ignores the unknown key.
//!
//! # The fleet service (protocol v4)
//!
//! [`PROTOCOL_VERSION`] 4 turns the daemon into a **multi-space fleet
//! service**: one process hosts an independent factor + lease table per
//! search space, keyed by the space *fingerprint*
//! ([`SearchSpace::fingerprint`] — a stable FNV-1a 64 over every
//! parameter's name/range/step). Three wire changes:
//!
//! ```text
//! -> {"type":"hello","version":4,"space":"<16 hex>","dim":<d>}
//! <- {"type":"hello-err","reason":"..."}                typed refusal
//! -> {"type":"sync-factor","from_n":<n>,"max_rows":<k>,"quantise":true}
//! <- {"type":"factor-delta",...,"pending":<rows left>,
//!     "factor_q":"<8 hex per value>","factor_r":"<hex>[.<hex>...]"}
//! ```
//!
//! The fingerprint rides as a 16-digit hex *string* because JSON numbers
//! are f64s and cannot carry every u64 exactly. A `hello` without
//! `"space"` (every v2/v3 peer) binds the daemon's default space; a
//! fingerprinted `hello` for the wrong space gets `hello-err` instead of
//! the old silent drop-with-warning. `max_rows` bounds one catch-up
//! chunk: the daemon truncates the delta to at most that many rows and
//! reports how many remain in `"pending"` (omitted when 0), so a cold
//! replica resumes row-by-row across chunks — and across reconnects,
//! since every imported chunk advances its `from_n`. `quantise` switches
//! the packed factor suffix to the **quantised-with-exact-residual**
//! encoding: per value, `factor_q` carries the f32 quantisation as 8 hex
//! digits and `factor_r` the XOR residual `bits(v) ^ bits((v as f32) as
//! f64)` in variable-length hex — decode is pure bit reassembly, so the
//! import stays *bit-identical* while a typical suffix (residuals have
//! only the low ~29 bits set) shrinks well below the decimal `"factor"`
//! array. Old daemons ignore both knobs and answer one full un-quantised
//! delta with no `"pending"`, which a chunking replica treats as the
//! final chunk.

use crate::gp::{GpHyper, KernelKind, SurrogateDelta, UNBOUNDED_HISTORY};
use crate::space::{Config, SearchSpace};
use crate::util::json::{parse, Json};

/// Wire-protocol version: 1 was the implicit evaluate-only protocol, 2
/// adds the handshake and the surrogate plane, 3 adds K-objective target
/// columns on `tell-obs` / `factor-delta` rows, 4 adds the fleet service
/// (fingerprinted `hello`, typed `hello-err`, chunked and quantised
/// `sync-factor`). Peers negotiate the *minimum* of their versions via
/// `hello`/`hello-ok`: a v2/v3 peer against a v4 daemon keeps working,
/// single-space and unchunked.
pub const PROTOCOL_VERSION: u32 = 4;

/// Parsed request.
#[derive(Debug, Clone, PartialEq)]
pub enum Request {
    Describe,
    Evaluate { config: Config, trial: Option<u64> },
    Shutdown,
}

/// Parsed response.
#[derive(Debug, Clone, PartialEq)]
pub enum Response {
    Target { description: String },
    Result { value: f64, cost_s: f64, config: Config, trial: Option<u64> },
    Error { message: String, trial: Option<u64> },
    Bye,
}

fn push_trial(pairs: &mut Vec<(&str, Json)>, trial: &Option<u64>) {
    if let Some(id) = trial {
        pairs.push(("trial", Json::from(*id as i64)));
    }
}

fn get_trial(j: &Json) -> Option<u64> {
    j.get("trial").and_then(Json::as_i64).and_then(|t| u64::try_from(t).ok())
}

pub fn encode_request(req: &Request, space: &SearchSpace) -> String {
    match req {
        Request::Describe => Json::obj(vec![("type", "describe".into())]).to_string(),
        Request::Evaluate { config, trial } => {
            let mut pairs = vec![
                ("type", "evaluate".into()),
                ("config", space.config_to_json(config)),
            ];
            push_trial(&mut pairs, trial);
            Json::obj(pairs).to_string()
        }
        Request::Shutdown => Json::obj(vec![("type", "shutdown".into())]).to_string(),
    }
}

pub fn decode_request(line: &str, space: &SearchSpace) -> Result<Request, String> {
    let j = parse(line).map_err(|e| e.to_string())?;
    match j.get("type").and_then(Json::as_str) {
        Some("describe") => Ok(Request::Describe),
        Some("evaluate") => {
            let config = space.config_from_json(j.req("config").map_err(|e| e.to_string())?)?;
            Ok(Request::Evaluate { config, trial: get_trial(&j) })
        }
        Some("shutdown") => Ok(Request::Shutdown),
        other => Err(format!("unknown request type {other:?}")),
    }
}

pub fn encode_response(resp: &Response, space: &SearchSpace) -> String {
    match resp {
        Response::Target { description } => Json::obj(vec![
            ("type", "target".into()),
            ("description", description.as_str().into()),
        ])
        .to_string(),
        Response::Result { value, cost_s, config, trial } => {
            let mut pairs = vec![
                ("type", "result".into()),
                ("value", (*value).into()),
                ("cost_s", (*cost_s).into()),
                ("config", space.config_to_json(config)),
            ];
            push_trial(&mut pairs, trial);
            Json::obj(pairs).to_string()
        }
        Response::Error { message, trial } => {
            let mut pairs = vec![
                ("type", "error".into()),
                ("message", message.as_str().into()),
            ];
            push_trial(&mut pairs, trial);
            Json::obj(pairs).to_string()
        }
        Response::Bye => Json::obj(vec![("type", "bye".into())]).to_string(),
    }
}

pub fn decode_response(line: &str, space: &SearchSpace) -> Result<Response, String> {
    let j = parse(line).map_err(|e| e.to_string())?;
    match j.get("type").and_then(Json::as_str) {
        Some("target") => Ok(Response::Target {
            description: j
                .get("description")
                .and_then(Json::as_str)
                .unwrap_or("")
                .to_string(),
        }),
        Some("result") => {
            let value = j
                .get("value")
                .and_then(Json::as_f64)
                .ok_or("result missing value")?;
            let cost_s = j.get("cost_s").and_then(Json::as_f64).unwrap_or(0.0);
            let config = space.config_from_json(j.req("config").map_err(|e| e.to_string())?)?;
            Ok(Response::Result { value, cost_s, config, trial: get_trial(&j) })
        }
        Some("error") => Ok(Response::Error {
            message: j.get("message").and_then(Json::as_str).unwrap_or("").to_string(),
            trial: get_trial(&j),
        }),
        Some("bye") => Ok(Response::Bye),
        other => Err(format!("unknown response type {other:?}")),
    }
}

// ---------------------------------------------------------------------------
// The surrogate plane (protocol v2). Space-free: x rows are unit-cube
// coordinates, so these codecs need no SearchSpace.
// ---------------------------------------------------------------------------

/// Parsed surrogate-plane request (module docs for the wire format).
#[derive(Debug, Clone, PartialEq)]
pub enum SurrogateRequest {
    /// Protocol-version handshake. `fingerprint`/`dim` (v4) name the
    /// search space this connection wants to condition
    /// ([`SearchSpace::fingerprint`] plus its dimension, which the fleet
    /// needs to build the space's store); `None` — every v2/v3 peer —
    /// binds the daemon's default space.
    Hello { version: u32, fingerprint: Option<u64>, dim: Option<usize> },
    /// Fire-and-forget observation append (no response on success).
    /// `ys` holds the secondary objective columns (v3; empty =
    /// single-objective, the only form a v2 peer sends). NaN entries
    /// mark declared columns this trial could not measure and travel as
    /// JSON `null`.
    TellObs { x: Vec<f64>, y: f64, ys: Vec<f64> },
    /// Catch-up request: everything past the replica's `from_n` rows.
    /// `max_rows` (v4) bounds the answer to one resumable chunk;
    /// `quantise` (v4) asks for the quantised-with-exact-residual factor
    /// encoding. Both default off, which is what v2/v3 peers send.
    SyncFactor { from_n: usize, max_rows: Option<usize>, quantise: bool },
    /// Publish this connection's in-flight `(x, lie)` points as a lease.
    AskLease { points: Vec<(Vec<f64>, f64)> },
    /// Retract a lease this connection published earlier.
    RetractLease { id: u64 },
    /// Switch the served factor's hyperparameters (write-through).
    SetHyper { hyper: GpHyper },
}

/// Parsed surrogate-plane response.
#[derive(Debug, Clone, PartialEq)]
pub enum SurrogateResponse {
    HelloOk { version: u32 },
    /// Typed handshake refusal (v4): the daemon will not serve this
    /// connection's space — wrong fingerprint for an existing dimension,
    /// fleet at `--max-spaces`, or a malformed fingerprinted hello.
    /// Unlike the generic `error`, receiving this means *connecting was
    /// the mistake*, so clients surface it instead of retrying.
    HelloErr { reason: String },
    /// One catch-up chunk. `pending` (v4) counts the store rows still
    /// beyond this chunk — 0 (the only value pre-v4 daemons produce)
    /// means the replica is caught up. `quantised` records which factor
    /// encoding rode the wire; the decoded `delta.factor` is
    /// bit-identical either way.
    FactorDelta { delta: SurrogateDelta, pending: usize, quantised: bool },
    Lease { id: u64 },
    LeaseOk { id: u64 },
    HyperOk,
    Error { message: String },
}

pub(crate) fn hyper_to_json(h: &GpHyper) -> Json {
    Json::obj(vec![
        ("lengthscale", h.lengthscale.into()),
        ("signal_var", h.signal_var.into()),
        ("noise_var", h.noise_var.into()),
        ("kernel", h.kernel.name().into()),
        (
            "max_history",
            if h.max_history == UNBOUNDED_HISTORY {
                Json::Null
            } else {
                h.max_history.into()
            },
        ),
    ])
}

fn req_f64(j: &Json, key: &str) -> Result<f64, String> {
    j.get(key).and_then(Json::as_f64).ok_or_else(|| format!("missing number '{key}'"))
}

fn req_usize(j: &Json, key: &str) -> Result<usize, String> {
    j.get(key)
        .and_then(Json::as_i64)
        .and_then(|n| usize::try_from(n).ok())
        .ok_or_else(|| format!("missing non-negative integer '{key}'"))
}

fn req_u64(j: &Json, key: &str) -> Result<u64, String> {
    j.get(key)
        .and_then(Json::as_i64)
        .and_then(|n| u64::try_from(n).ok())
        .ok_or_else(|| format!("missing non-negative integer '{key}'"))
}

pub(crate) fn hyper_from_json(j: &Json) -> Result<GpHyper, String> {
    let kname =
        j.get("kernel").and_then(Json::as_str).ok_or_else(|| "missing 'kernel'".to_string())?;
    let kernel = KernelKind::parse(kname).ok_or_else(|| format!("unknown kernel '{kname}'"))?;
    let max_history = match j.get("max_history") {
        None | Some(Json::Null) => UNBOUNDED_HISTORY,
        Some(v) => v
            .as_i64()
            .and_then(|n| usize::try_from(n).ok())
            .filter(|&w| w > 0)
            .ok_or_else(|| "bad 'max_history'".to_string())?,
    };
    Ok(GpHyper {
        lengthscale: req_f64(j, "lengthscale")?,
        signal_var: req_f64(j, "signal_var")?,
        noise_var: req_f64(j, "noise_var")?,
        kernel,
        max_history,
    })
}

pub(crate) fn f64_vec(j: &Json) -> Result<Vec<f64>, String> {
    j.as_arr()
        .ok_or_else(|| "expected an array of numbers".to_string())?
        .iter()
        .map(|v| v.as_f64().ok_or_else(|| "expected a number".to_string()))
        .collect()
}

/// Quantised-with-exact-residual factor encoding (v4). Per value `v`:
/// `factor_q` appends the f32 quantisation's bits as exactly 8 hex
/// digits, `factor_r` appends the XOR residual
/// `bits(v) ^ bits((v as f32) as f64)` in variable-length hex,
/// '.'-separated. Reassembly is pure bit manipulation — no float
/// arithmetic — so NaNs, infinities and subnormals all survive and the
/// decode is bit-identical by construction. Residuals of
/// f32-representable magnitudes keep only the low ~29 mantissa bits, so
/// the pair is measurably smaller than the decimal `"factor"` array.
pub(crate) fn factor_quantise(factor: &[f64]) -> (String, String) {
    let mut q = String::with_capacity(factor.len() * 8);
    let mut r = String::with_capacity(factor.len() * 9);
    for (i, &v) in factor.iter().enumerate() {
        let qbits = (v as f32).to_bits();
        q.push_str(&format!("{qbits:08x}"));
        if i > 0 {
            r.push('.');
        }
        r.push_str(&format!("{:x}", v.to_bits() ^ ((f32::from_bits(qbits) as f64).to_bits())));
    }
    (q, r)
}

pub(crate) fn factor_dequantise(q: &str, r: &str) -> Result<Vec<f64>, String> {
    if q.is_empty() && r.is_empty() {
        return Ok(Vec::new());
    }
    if q.len() % 8 != 0 {
        return Err(format!("factor_q length {} is not a multiple of 8", q.len()));
    }
    let n = q.len() / 8;
    let residuals: Vec<&str> = r.split('.').collect();
    if residuals.len() != n {
        return Err(format!("{n} quantised values but {} residuals", residuals.len()));
    }
    let mut out = Vec::with_capacity(n);
    for (i, rs) in residuals.iter().enumerate() {
        let qs = q.get(i * 8..i * 8 + 8).ok_or("factor_q is not ASCII hex")?;
        let qbits = u32::from_str_radix(qs, 16)
            .map_err(|_| format!("bad factor_q chunk '{qs}'"))?;
        let rbits = u64::from_str_radix(rs, 16)
            .map_err(|_| format!("bad factor_r chunk '{rs}'"))?;
        out.push(f64::from_bits((f32::from_bits(qbits) as f64).to_bits() ^ rbits));
    }
    Ok(out)
}

/// `(x, value)` points under `value_key` ("y" for observation rows, "lie"
/// for lease points).
fn points_to_json(points: &[(Vec<f64>, f64)], value_key: &str) -> Json {
    Json::Arr(
        points
            .iter()
            .map(|(x, v)| Json::obj(vec![("x", Json::from_f64s(x)), (value_key, (*v).into())]))
            .collect(),
    )
}

fn points_from_json(j: &Json, value_key: &str) -> Result<Vec<(Vec<f64>, f64)>, String> {
    j.as_arr()
        .ok_or_else(|| "expected an array of points".to_string())?
        .iter()
        .map(|p| {
            let x = f64_vec(p.req("x").map_err(|e| e.to_string())?)?;
            Ok((x, req_f64(p, value_key)?))
        })
        .collect()
}

/// Secondary objective columns: NaN (a declared-but-missing column) is
/// not valid JSON, so it travels as `null` and decodes back to NaN.
pub(crate) fn ys_to_json(ys: &[f64]) -> Json {
    Json::Arr(
        ys.iter()
            .map(|&v| if v.is_finite() { Json::Num(v) } else { Json::Null })
            .collect(),
    )
}

pub(crate) fn ys_from_json(j: &Json) -> Result<Vec<f64>, String> {
    j.as_arr()
        .ok_or_else(|| "expected an array of objective columns".to_string())?
        .iter()
        .map(|v| match v {
            // Only null means "column not measured" (NaN in memory);
            // any other non-number is a producer bug and must surface
            // as a decode error, exactly like every other f64 field.
            Json::Null => Ok(f64::NAN),
            other => other
                .as_f64()
                .ok_or_else(|| "objective column must be a number or null".to_string()),
        })
        .collect()
}

/// Observation rows with their per-row secondary columns: each row is
/// `{"x":..,"y":..}` plus `"ys"` when that row carries extras.
pub(crate) fn rows_to_json(rows: &[(Vec<f64>, f64)], extras: &[Vec<f64>]) -> Json {
    Json::Arr(
        rows.iter()
            .enumerate()
            .map(|(i, (x, y))| {
                let mut pairs = vec![("x", Json::from_f64s(x)), ("y", (*y).into())];
                if let Some(e) = extras.get(i) {
                    if !e.is_empty() {
                        pairs.push(("ys", ys_to_json(e)));
                    }
                }
                Json::obj(pairs)
            })
            .collect(),
    )
}

#[allow(clippy::type_complexity)]
pub(crate) fn rows_from_json(j: &Json) -> Result<(Vec<(Vec<f64>, f64)>, Vec<Vec<f64>>), String> {
    let arr = j.as_arr().ok_or_else(|| "expected an array of rows".to_string())?;
    let mut rows = Vec::with_capacity(arr.len());
    let mut extras = Vec::with_capacity(arr.len());
    for p in arr {
        let x = f64_vec(p.req("x").map_err(|e| e.to_string())?)?;
        rows.push((x, req_f64(p, "y")?));
        extras.push(match p.get("ys") {
            Some(v) => ys_from_json(v)?,
            None => Vec::new(),
        });
    }
    // Canonical form for all-single-objective deltas (what a v2 peer
    // sends): no extras vector at all.
    if extras.iter().all(Vec::is_empty) {
        extras.clear();
    }
    Ok((rows, extras))
}

pub fn encode_surrogate_request(req: &SurrogateRequest) -> String {
    match req {
        SurrogateRequest::Hello { version, fingerprint, dim } => {
            let mut pairs = vec![
                ("type", "hello".into()),
                ("version", (*version as i64).into()),
            ];
            if let Some(fp) = fingerprint {
                pairs.push(("space", format!("{fp:016x}").as_str().into()));
            }
            if let Some(d) = dim {
                pairs.push(("dim", (*d).into()));
            }
            Json::obj(pairs).to_string()
        }
        SurrogateRequest::TellObs { x, y, ys } => {
            let mut pairs = vec![
                ("type", "tell-obs".into()),
                ("x", Json::from_f64s(x)),
                ("y", (*y).into()),
            ];
            if !ys.is_empty() {
                pairs.push(("ys", ys_to_json(ys)));
            }
            Json::obj(pairs).to_string()
        }
        SurrogateRequest::SyncFactor { from_n, max_rows, quantise } => {
            let mut pairs = vec![
                ("type", "sync-factor".into()),
                ("from_n", (*from_n).into()),
            ];
            if let Some(k) = max_rows {
                pairs.push(("max_rows", (*k).into()));
            }
            if *quantise {
                pairs.push(("quantise", Json::Bool(true)));
            }
            Json::obj(pairs).to_string()
        }
        SurrogateRequest::AskLease { points } => Json::obj(vec![
            ("type", "ask-lease".into()),
            ("points", points_to_json(points, "lie")),
        ])
        .to_string(),
        SurrogateRequest::RetractLease { id } => Json::obj(vec![
            ("type", "retract-lease".into()),
            ("id", (*id as i64).into()),
        ])
        .to_string(),
        SurrogateRequest::SetHyper { hyper } => Json::obj(vec![
            ("type", "set-hyper".into()),
            ("hyper", hyper_to_json(hyper)),
        ])
        .to_string(),
    }
}

pub fn decode_surrogate_request(line: &str) -> Result<SurrogateRequest, String> {
    let j = parse(line).map_err(|e| e.to_string())?;
    match j.get("type").and_then(Json::as_str) {
        Some("hello") => {
            let fingerprint = match j.get("space") {
                None | Some(Json::Null) => None,
                Some(v) => Some(
                    v.as_str()
                        .filter(|s| s.len() == 16)
                        .and_then(|s| u64::from_str_radix(s, 16).ok())
                        .ok_or_else(|| {
                            "'space' must be a 16-digit hex fingerprint".to_string()
                        })?,
                ),
            };
            let dim = match j.get("dim") {
                None | Some(Json::Null) => None,
                Some(_) => Some(req_usize(&j, "dim")?),
            };
            Ok(SurrogateRequest::Hello {
                version: req_u64(&j, "version")?
                    .try_into()
                    .map_err(|_| "version out of range".to_string())?,
                fingerprint,
                dim,
            })
        }
        Some("tell-obs") => Ok(SurrogateRequest::TellObs {
            x: f64_vec(j.req("x").map_err(|e| e.to_string())?)?,
            y: req_f64(&j, "y")?,
            ys: match j.get("ys") {
                Some(v) => ys_from_json(v)?,
                None => Vec::new(),
            },
        }),
        Some("sync-factor") => Ok(SurrogateRequest::SyncFactor {
            from_n: req_usize(&j, "from_n")?,
            max_rows: match j.get("max_rows") {
                None | Some(Json::Null) => None,
                Some(_) => Some(req_usize(&j, "max_rows")?),
            },
            quantise: match j.get("quantise") {
                None => false,
                Some(v) => v.as_bool().ok_or("'quantise' must be a boolean")?,
            },
        }),
        Some("ask-lease") => Ok(SurrogateRequest::AskLease {
            points: points_from_json(j.req("points").map_err(|e| e.to_string())?, "lie")?,
        }),
        Some("retract-lease") => Ok(SurrogateRequest::RetractLease { id: req_u64(&j, "id")? }),
        Some("set-hyper") => Ok(SurrogateRequest::SetHyper {
            hyper: hyper_from_json(j.req("hyper").map_err(|e| e.to_string())?)?,
        }),
        other => Err(format!("unknown surrogate request type {other:?}")),
    }
}

pub fn encode_surrogate_response(resp: &SurrogateResponse) -> String {
    match resp {
        SurrogateResponse::HelloOk { version } => Json::obj(vec![
            ("type", "hello-ok".into()),
            ("version", (*version as i64).into()),
        ])
        .to_string(),
        SurrogateResponse::HelloErr { reason } => Json::obj(vec![
            ("type", "hello-err".into()),
            ("reason", reason.as_str().into()),
        ])
        .to_string(),
        SurrogateResponse::FactorDelta { delta: d, pending, quantised } => {
            let mut pairs = vec![
                ("type", "factor-delta".into()),
                ("from_n", d.from_n.into()),
                ("total_n", d.total_n.into()),
                ("hyper", hyper_to_json(&d.hyper)),
                ("rows", rows_to_json(&d.rows, &d.extras)),
            ];
            match (&d.factor, *quantised) {
                (Some(f), true) => {
                    let (q, r) = factor_quantise(f);
                    pairs.push(("factor_q", q.as_str().into()));
                    pairs.push(("factor_r", r.as_str().into()));
                }
                (Some(f), false) => pairs.push(("factor", Json::from_f64s(f))),
                (None, _) => pairs.push(("factor", Json::Null)),
            }
            pairs.push(("leases", points_to_json(&d.leases, "lie")));
            if *pending > 0 {
                pairs.push(("pending", (*pending).into()));
            }
            Json::obj(pairs).to_string()
        }
        SurrogateResponse::Lease { id } => Json::obj(vec![
            ("type", "lease".into()),
            ("id", (*id as i64).into()),
        ])
        .to_string(),
        SurrogateResponse::LeaseOk { id } => Json::obj(vec![
            ("type", "lease-ok".into()),
            ("id", (*id as i64).into()),
        ])
        .to_string(),
        SurrogateResponse::HyperOk => {
            Json::obj(vec![("type", "hyper-ok".into())]).to_string()
        }
        SurrogateResponse::Error { message } => Json::obj(vec![
            ("type", "error".into()),
            ("message", message.as_str().into()),
        ])
        .to_string(),
    }
}

pub fn decode_surrogate_response(line: &str) -> Result<SurrogateResponse, String> {
    let j = parse(line).map_err(|e| e.to_string())?;
    match j.get("type").and_then(Json::as_str) {
        Some("hello-ok") => Ok(SurrogateResponse::HelloOk {
            version: req_u64(&j, "version")?
                .try_into()
                .map_err(|_| "version out of range".to_string())?,
        }),
        Some("hello-err") => Ok(SurrogateResponse::HelloErr {
            reason: j.get("reason").and_then(Json::as_str).unwrap_or("").to_string(),
        }),
        Some("factor-delta") => {
            let (factor, quantised) = match (j.get("factor_q"), j.get("factor")) {
                (Some(q), _) => {
                    let q = q.as_str().ok_or("'factor_q' must be a hex string")?;
                    let r = j
                        .get("factor_r")
                        .and_then(Json::as_str)
                        .ok_or("'factor_q' without a 'factor_r' residual string")?;
                    (Some(factor_dequantise(q, r)?), true)
                }
                (None, None | Some(Json::Null)) => (None, false),
                (None, Some(v)) => (Some(f64_vec(v)?), false),
            };
            let (rows, extras) = rows_from_json(j.req("rows").map_err(|e| e.to_string())?)?;
            Ok(SurrogateResponse::FactorDelta {
                delta: SurrogateDelta {
                    from_n: req_usize(&j, "from_n")?,
                    total_n: req_usize(&j, "total_n")?,
                    hyper: hyper_from_json(j.req("hyper").map_err(|e| e.to_string())?)?,
                    rows,
                    extras,
                    factor,
                    leases: points_from_json(
                        j.req("leases").map_err(|e| e.to_string())?,
                        "lie",
                    )?,
                },
                pending: match j.get("pending") {
                    None | Some(Json::Null) => 0,
                    Some(_) => req_usize(&j, "pending")?,
                },
                quantised,
            })
        }
        Some("lease") => Ok(SurrogateResponse::Lease { id: req_u64(&j, "id")? }),
        Some("lease-ok") => Ok(SurrogateResponse::LeaseOk { id: req_u64(&j, "id")? }),
        Some("hyper-ok") => Ok(SurrogateResponse::HyperOk),
        Some("error") => Ok(SurrogateResponse::Error {
            message: j.get("message").and_then(Json::as_str).unwrap_or("").to_string(),
        }),
        other => Err(format!("unknown surrogate response type {other:?}")),
    }
}

// -- the observability plane (`surrogate-serve --events-addr`) --------------
//
// A third, read-only plane on its *own* listener: a subscriber sends one
// `{"type":"subscribe"}` line, the publisher answers with an `obs-hello`
// carrying the cumulative dropped-record counter and each source's next
// sequence number (the resume point), then streams raw event lines (see
// `obs::encode_event_record`). Anything other than a well-formed
// subscribe gets one `error` line and a close — per-connection, like
// every other plane.

/// The subscribe line a dashboard sends to `--events-addr`.
pub fn encode_obs_subscribe() -> String {
    Json::obj(vec![("type", "subscribe".into())]).to_string()
}

/// Validate a subscribe line. Strict: the only accepted frame is a JSON
/// object whose `"type"` is `"subscribe"` — the event plane is read-only
/// and anything else is hostile.
pub fn decode_obs_subscribe(line: &str) -> Result<(), String> {
    let j = parse(line).map_err(|e| e.to_string())?;
    match j.get("type").and_then(Json::as_str) {
        Some("subscribe") => Ok(()),
        Some(other) => Err(format!("unknown event-plane request type {other:?}")),
        None => Err("missing 'type'".to_string()),
    }
}

/// The publisher's greeting: cumulative drop counter + per-source next
/// sequence numbers, so a (re)connecting subscriber knows where the
/// stream it is about to receive resumes.
pub fn encode_obs_hello(dropped: u64, seqs: &[(String, u64)]) -> String {
    Json::obj(vec![
        ("type", "obs-hello".into()),
        ("dropped", Json::Num(dropped as f64)),
        (
            "seqs",
            Json::Obj(
                seqs.iter()
                    .map(|(name, next)| (name.clone(), Json::Num(*next as f64)))
                    .collect(),
            ),
        ),
    ])
    .to_string()
}

/// Decode an `obs-hello` into `(dropped, per-source next seqs)`.
pub fn decode_obs_hello(line: &str) -> Result<(u64, Vec<(String, u64)>), String> {
    let j = parse(line).map_err(|e| e.to_string())?;
    if j.get("type").and_then(Json::as_str) != Some("obs-hello") {
        return Err("expected an obs-hello line".to_string());
    }
    let dropped = req_u64(&j, "dropped")?;
    let seqs = match j.get("seqs") {
        Some(Json::Obj(map)) => map
            .iter()
            .map(|(name, v)| {
                v.as_f64()
                    .filter(|x| *x >= 0.0)
                    .map(|x| (name.clone(), x as u64))
                    .ok_or_else(|| format!("seq for source '{name}' must be a number"))
            })
            .collect::<Result<Vec<_>, String>>()?,
        _ => return Err("missing 'seqs' object".to_string()),
    };
    Ok((dropped, seqs))
}

/// One `error` line for a hostile event-plane frame (shared shape with
/// the evaluate/surrogate planes).
pub fn encode_obs_error(message: &str) -> String {
    Json::obj(vec![("type", "error".into()), ("message", message.into())]).to_string()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::space::threading_space;
    use crate::util::prop;

    fn space() -> SearchSpace {
        threading_space(64, 1024, 64)
    }

    #[test]
    fn request_round_trip() {
        let s = space();
        for req in [
            Request::Describe,
            Request::Evaluate { config: vec![2, 10, 128, 30, 20], trial: None },
            Request::Evaluate { config: vec![2, 10, 128, 30, 20], trial: Some(7) },
            Request::Shutdown,
        ] {
            let line = encode_request(&req, &s);
            assert_eq!(decode_request(&line, &s).unwrap(), req, "line: {line}");
        }
    }

    #[test]
    fn response_round_trip() {
        let s = space();
        for resp in [
            Response::Target { description: "sim:X".into() },
            Response::Result {
                value: 123.5,
                cost_s: 0.25,
                config: vec![1, 1, 64, 0, 1],
                trial: None,
            },
            Response::Result {
                value: 9.0,
                cost_s: 0.0,
                config: vec![1, 1, 64, 0, 1],
                trial: Some(41),
            },
            Response::Error { message: "boom \"quoted\"".into(), trial: Some(3) },
            Response::Error { message: "untagged".into(), trial: None },
            Response::Bye,
        ] {
            let line = encode_response(&resp, &s);
            assert_eq!(decode_response(&line, &s).unwrap(), resp, "line: {line}");
        }
    }

    #[test]
    fn legacy_untagged_result_decodes() {
        // A pre-ask/tell peer sends results without trial/cost fields.
        let s = space();
        let cfg = vec![1, 1, 64, 0, 1];
        let line = format!(
            r#"{{"type":"result","value":5.5,"config":{}}}"#,
            s.config_to_json(&cfg)
        );
        match decode_response(&line, &s).unwrap() {
            Response::Result { value, cost_s, trial, .. } => {
                assert_eq!(value, 5.5);
                assert_eq!(cost_s, 0.0);
                assert_eq!(trial, None);
            }
            other => panic!("unexpected {other:?}"),
        }
    }

    #[test]
    fn rejects_garbage() {
        let s = space();
        assert!(decode_request("not json", &s).is_err());
        assert!(decode_request(r#"{"type":"nope"}"#, &s).is_err());
        assert!(decode_response(r#"{"type":"result"}"#, &s).is_err());
    }

    #[test]
    fn surrogate_request_round_trip() {
        let hyper = GpHyper { lengthscale: 0.35, max_history: 32, ..GpHyper::default() };
        for req in [
            SurrogateRequest::Hello {
                version: PROTOCOL_VERSION,
                fingerprint: None,
                dim: None,
            },
            SurrogateRequest::Hello {
                version: PROTOCOL_VERSION,
                fingerprint: Some(space().fingerprint()),
                dim: Some(space().dim()),
            },
            // A fingerprint with the high bit set: JSON numbers are f64s,
            // so this only survives because it rides as a hex string.
            SurrogateRequest::Hello {
                version: PROTOCOL_VERSION,
                fingerprint: Some(0xdead_beef_0000_0001),
                dim: Some(3),
            },
            SurrogateRequest::TellObs { x: vec![0.25, 0.5, 1.0], y: -3.125, ys: Vec::new() },
            SurrogateRequest::TellObs {
                x: vec![0.25, 0.5],
                y: 2.0,
                ys: vec![-1.5, 0.625],
            },
            SurrogateRequest::SyncFactor { from_n: 17, max_rows: None, quantise: false },
            SurrogateRequest::SyncFactor { from_n: 0, max_rows: Some(64), quantise: true },
            SurrogateRequest::AskLease { points: vec![(vec![0.1, 0.9], 0.0)] },
            SurrogateRequest::AskLease { points: Vec::new() },
            SurrogateRequest::RetractLease { id: 41 },
            SurrogateRequest::SetHyper { hyper },
        ] {
            let line = encode_surrogate_request(&req);
            assert_eq!(decode_surrogate_request(&line).unwrap(), req, "line: {line}");
        }
    }

    #[test]
    fn surrogate_response_round_trip() {
        let delta = SurrogateDelta {
            from_n: 2,
            total_n: 4,
            hyper: GpHyper::default(),
            rows: vec![(vec![0.5, 0.25], 1.5), (vec![0.125, 0.75], -0.5)],
            extras: vec![vec![-2.5], Vec::new()],
            factor: Some(vec![1.0, 0.5, 0.875, 0.25, 0.125, 1.5, 0.0]),
            leases: vec![(vec![0.3, 0.3], 0.0)],
        };
        for resp in [
            SurrogateResponse::HelloOk { version: PROTOCOL_VERSION },
            SurrogateResponse::HelloErr {
                reason: "space 0123456789abcdef: dimension 3 != served 5".into(),
            },
            SurrogateResponse::FactorDelta {
                delta: delta.clone(),
                pending: 0,
                quantised: false,
            },
            SurrogateResponse::FactorDelta {
                delta: delta.clone(),
                pending: 9,
                quantised: true,
            },
            SurrogateResponse::FactorDelta {
                delta: SurrogateDelta { factor: None, ..delta },
                pending: 0,
                quantised: false,
            },
            SurrogateResponse::Lease { id: 7 },
            SurrogateResponse::LeaseOk { id: 7 },
            SurrogateResponse::HyperOk,
            SurrogateResponse::Error { message: "boom \"quoted\"".into() },
        ] {
            let line = encode_surrogate_response(&resp);
            assert_eq!(decode_surrogate_response(&line).unwrap(), resp, "line: {line}");
        }
    }

    #[test]
    fn prop_quantised_factor_is_bit_identical_and_smaller() {
        // The quantised encoding must reassemble every f64 bit pattern —
        // including specials quantisation mangles — and beat the decimal
        // array on realistic (f32-magnitude) factor suffixes.
        prop::check("quantised factor codec", 50, |rng| {
            let mut factor: Vec<f64> = (0..64)
                .map(|_| (rng.f64() - 0.5) * 10f64.powi(rng.range_i64(-6, 6) as i32))
                .collect();
            factor.extend([0.0, -0.0, f64::NAN, f64::INFINITY, f64::NEG_INFINITY, 5e-324]);
            let (q, r) = factor_quantise(&factor);
            let back = factor_dequantise(&q, &r).unwrap();
            assert_eq!(back.len(), factor.len());
            for (a, b) in factor.iter().zip(&back) {
                assert_eq!(a.to_bits(), b.to_bits(), "{a} re-decoded as {b}");
            }
            let decimal = Json::from_f64s(&factor).to_string().len();
            assert!(
                q.len() + r.len() < decimal,
                "quantised {} + {} bytes vs decimal {decimal}",
                q.len(),
                r.len()
            );
        });
        assert_eq!(factor_dequantise("", "").unwrap(), Vec::<f64>::new());
        assert!(factor_dequantise("0123456", "0").is_err(), "truncated factor_q");
        assert!(factor_dequantise("3f800000", "0.0").is_err(), "residual count mismatch");
        assert!(factor_dequantise("3f80000g", "0").is_err(), "non-hex factor_q");
    }

    #[test]
    fn pending_zero_is_omitted_and_defaults() {
        // Canonical form: pre-v4 daemons never write "pending", and a v4
        // daemon with nothing left matches them byte-for-byte.
        let resp = SurrogateResponse::FactorDelta {
            delta: SurrogateDelta {
                from_n: 0,
                total_n: 0,
                hyper: GpHyper::default(),
                rows: Vec::new(),
                extras: Vec::new(),
                factor: None,
                leases: Vec::new(),
            },
            pending: 0,
            quantised: false,
        };
        let line = encode_surrogate_response(&resp);
        assert!(!line.contains("pending"), "line: {line}");
        assert_eq!(decode_surrogate_response(&line).unwrap(), resp);
    }

    #[test]
    fn unbounded_window_survives_the_wire() {
        let hyper =
            GpHyper { max_history: crate::gp::UNBOUNDED_HISTORY, ..GpHyper::default() };
        let line = encode_surrogate_request(&SurrogateRequest::SetHyper { hyper });
        assert!(line.contains(r#""max_history":null"#), "line: {line}");
        match decode_surrogate_request(&line).unwrap() {
            SurrogateRequest::SetHyper { hyper: h } => {
                assert_eq!(h.max_history, crate::gp::UNBOUNDED_HISTORY)
            }
            other => panic!("unexpected {other:?}"),
        }
    }

    #[test]
    fn surrogate_rejects_garbage() {
        assert!(decode_surrogate_request("not json").is_err());
        assert!(decode_surrogate_request(r#"{"type":"evaluate"}"#).is_err());
        assert!(decode_surrogate_request(r#"{"type":"tell-obs","x":"nope","y":1}"#).is_err());
        assert!(decode_surrogate_response(r#"{"type":"factor-delta"}"#).is_err());
        assert!(decode_surrogate_request(r#"{"type":"sync-factor","from_n":-1}"#).is_err());
        assert!(
            decode_surrogate_request(r#"{"type":"tell-obs","x":[0.5],"y":1,"ys":7}"#).is_err(),
            "non-array ys must be refused"
        );
        assert!(
            decode_surrogate_request(r#"{"type":"tell-obs","x":[0.5],"y":1,"ys":["1.5"]}"#)
                .is_err(),
            "a non-numeric column is a producer bug, not a NaN"
        );
        assert!(
            decode_surrogate_request(r#"{"type":"hello","version":4,"space":"xyz"}"#).is_err(),
            "a malformed fingerprint must be refused, not bound to a space"
        );
        assert!(
            decode_surrogate_request(r#"{"type":"hello","version":4,"space":"00000000000000001"}"#)
                .is_err(),
            "a 17-digit fingerprint is not a u64"
        );
        assert!(decode_surrogate_request(
            r#"{"type":"sync-factor","from_n":0,"quantise":"yes"}"#
        )
        .is_err());
        assert!(
            decode_surrogate_response(
                r#"{"type":"factor-delta","from_n":0,"total_n":0,
                    "hyper":{"lengthscale":0.2,"signal_var":1.0,"noise_var":0.001,
                             "kernel":"rbf"},
                    "rows":[],"factor_q":"3f800000","leases":[]}"#
                    .replace('\n', "")
                    .as_str()
            )
            .is_err(),
            "factor_q without factor_r must be refused"
        );
    }

    #[test]
    fn nan_objective_column_travels_as_null() {
        // A declared-but-unmeasured column is NaN in memory; JSON cannot
        // represent NaN, so it rides as null and decodes back to NaN —
        // the degradation marker survives the wire.
        let req = SurrogateRequest::TellObs {
            x: vec![0.5, 0.25],
            y: 3.0,
            ys: vec![f64::NAN, -1.25],
        };
        let line = encode_surrogate_request(&req);
        assert!(line.contains("null"), "line: {line}");
        match decode_surrogate_request(&line).unwrap() {
            SurrogateRequest::TellObs { y, ys, .. } => {
                assert_eq!(y, 3.0);
                assert!(ys[0].is_nan());
                assert_eq!(ys[1], -1.25);
            }
            other => panic!("unexpected {other:?}"),
        }
    }

    #[test]
    fn v2_lines_still_decode_single_objective() {
        // A v2 peer never writes "ys": the v3+ decoder must accept its
        // lines unchanged (empty extras everywhere).
        match decode_surrogate_request(r#"{"type":"tell-obs","x":[0.5,0.25],"y":1.5}"#).unwrap()
        {
            SurrogateRequest::TellObs { ys, .. } => assert!(ys.is_empty()),
            other => panic!("unexpected {other:?}"),
        }
        // Nor does it write "space"/"dim" on hello or "max_rows" /
        // "quantise" on sync-factor: those decode to the single-space,
        // full-transfer defaults.
        assert_eq!(
            decode_surrogate_request(r#"{"type":"hello","version":2}"#).unwrap(),
            SurrogateRequest::Hello { version: 2, fingerprint: None, dim: None }
        );
        assert_eq!(
            decode_surrogate_request(r#"{"type":"sync-factor","from_n":3}"#).unwrap(),
            SurrogateRequest::SyncFactor { from_n: 3, max_rows: None, quantise: false }
        );
        let line = r#"{"type":"factor-delta","from_n":0,"total_n":1,
            "hyper":{"lengthscale":0.2,"signal_var":1.0,"noise_var":0.001,
                     "kernel":"rbf","max_history":64},
            "rows":[{"x":[0.5,0.5],"y":2.0}],"factor":null,"leases":[]}"#
            .replace('\n', "");
        match decode_surrogate_response(&line).unwrap() {
            SurrogateResponse::FactorDelta { delta: d, pending, quantised } => {
                assert_eq!(d.rows.len(), 1);
                assert!(d.extras.is_empty(), "v2 delta decodes with no extras");
                assert_eq!(pending, 0, "no 'pending' key means nothing left");
                assert!(!quantised);
            }
            other => panic!("unexpected {other:?}"),
        }
    }

    #[test]
    fn prop_surrogate_f64s_survive_the_wire_bit_exactly() {
        // The factor-suffix transfer relies on f64 round-tripping through
        // the JSON codec without rounding: shortest-round-trip encode,
        // correctly-rounded parse.
        prop::check("surrogate f64 wire round trip", 50, |rng| {
            let x: Vec<f64> = (0..5)
                .map(|_| (rng.f64() - 0.5) * 10f64.powi(rng.range_i64(-12, 12) as i32))
                .collect();
            let y = (rng.f64() - 0.5) * 1e6;
            let ys: Vec<f64> = (0..rng.index(3))
                .map(|_| (rng.f64() - 0.5) * 10f64.powi(rng.range_i64(-12, 12) as i32))
                .collect();
            let req = SurrogateRequest::TellObs { x: x.clone(), y, ys: ys.clone() };
            match decode_surrogate_request(&encode_surrogate_request(&req)).unwrap() {
                SurrogateRequest::TellObs { x: x2, y: y2, ys: ys2 } => {
                    assert_eq!(y.to_bits(), y2.to_bits());
                    for (a, b) in x.iter().zip(&x2) {
                        assert_eq!(a.to_bits(), b.to_bits(), "{a} re-decoded as {b}");
                    }
                    assert_eq!(ys.len(), ys2.len());
                    for (a, b) in ys.iter().zip(&ys2) {
                        assert_eq!(a.to_bits(), b.to_bits(), "column {a} re-decoded as {b}");
                    }
                }
                other => panic!("unexpected {other:?}"),
            }
        });
    }

    #[test]
    fn prop_evaluate_round_trip_any_config_and_id() {
        let s = space();
        prop::check("proto evaluate round trip", 100, |rng| {
            let config = s.random(rng);
            let trial = if rng.bool(0.5) { Some(rng.next_u64() >> 12) } else { None };
            let req = Request::Evaluate { config, trial };
            let line = encode_request(&req, &s);
            assert_eq!(decode_request(&line, &s).unwrap(), req);
        });
    }

    #[test]
    fn obs_subscribe_and_hello_round_trip() {
        assert!(decode_obs_subscribe(&encode_obs_subscribe()).is_ok());
        assert!(decode_obs_subscribe(r#"{"type":"evaluate"}"#).is_err());
        assert!(decode_obs_subscribe("garbage").is_err());
        assert!(decode_obs_subscribe("{}").is_err());

        let seqs = vec![("daemon".to_string(), 42u64), ("surrogate".to_string(), 0)];
        let line = encode_obs_hello(7, &seqs);
        let (dropped, back) = decode_obs_hello(&line).unwrap();
        assert_eq!(dropped, 7);
        assert_eq!(back, seqs);
        assert!(decode_obs_hello(r#"{"type":"hello-ok","version":4}"#).is_err());
        assert!(decode_obs_hello(r#"{"type":"obs-hello","dropped":0}"#).is_err());
    }
}
