//! Wire protocol for the host/target split (paper Fig. 4): JSON-lines
//! over TCP. One request per line, one response per line.
//!
//! Requests:
//!   {"type":"describe"}
//!   {"type":"evaluate","config":{"<param>":<int>,...}}
//!   {"type":"shutdown"}
//! Responses:
//!   {"type":"target","description":"..."}
//!   {"type":"result","value":<f64>,"config":{...}}
//!   {"type":"error","message":"..."}
//!   {"type":"bye"}

use crate::space::{Config, SearchSpace};
use crate::util::json::{parse, Json};

/// Parsed request.
#[derive(Debug, Clone, PartialEq)]
pub enum Request {
    Describe,
    Evaluate(Config),
    Shutdown,
}

/// Parsed response.
#[derive(Debug, Clone, PartialEq)]
pub enum Response {
    Target { description: String },
    Result { value: f64, config: Config },
    Error { message: String },
    Bye,
}

pub fn encode_request(req: &Request, space: &SearchSpace) -> String {
    match req {
        Request::Describe => Json::obj(vec![("type", "describe".into())]).to_string(),
        Request::Evaluate(cfg) => Json::obj(vec![
            ("type", "evaluate".into()),
            ("config", space.config_to_json(cfg)),
        ])
        .to_string(),
        Request::Shutdown => Json::obj(vec![("type", "shutdown".into())]).to_string(),
    }
}

pub fn decode_request(line: &str, space: &SearchSpace) -> Result<Request, String> {
    let j = parse(line).map_err(|e| e.to_string())?;
    match j.get("type").and_then(Json::as_str) {
        Some("describe") => Ok(Request::Describe),
        Some("evaluate") => {
            let cfg = space.config_from_json(j.req("config").map_err(|e| e.to_string())?)?;
            Ok(Request::Evaluate(cfg))
        }
        Some("shutdown") => Ok(Request::Shutdown),
        other => Err(format!("unknown request type {other:?}")),
    }
}

pub fn encode_response(resp: &Response, space: &SearchSpace) -> String {
    match resp {
        Response::Target { description } => Json::obj(vec![
            ("type", "target".into()),
            ("description", description.as_str().into()),
        ])
        .to_string(),
        Response::Result { value, config } => Json::obj(vec![
            ("type", "result".into()),
            ("value", (*value).into()),
            ("config", space.config_to_json(config)),
        ])
        .to_string(),
        Response::Error { message } => Json::obj(vec![
            ("type", "error".into()),
            ("message", message.as_str().into()),
        ])
        .to_string(),
        Response::Bye => Json::obj(vec![("type", "bye".into())]).to_string(),
    }
}

pub fn decode_response(line: &str, space: &SearchSpace) -> Result<Response, String> {
    let j = parse(line).map_err(|e| e.to_string())?;
    match j.get("type").and_then(Json::as_str) {
        Some("target") => Ok(Response::Target {
            description: j
                .get("description")
                .and_then(Json::as_str)
                .unwrap_or("")
                .to_string(),
        }),
        Some("result") => {
            let value = j
                .get("value")
                .and_then(Json::as_f64)
                .ok_or("result missing value")?;
            let cfg = space.config_from_json(j.req("config").map_err(|e| e.to_string())?)?;
            Ok(Response::Result { value, config: cfg })
        }
        Some("error") => Ok(Response::Error {
            message: j.get("message").and_then(Json::as_str).unwrap_or("").to_string(),
        }),
        Some("bye") => Ok(Response::Bye),
        other => Err(format!("unknown response type {other:?}")),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::space::threading_space;
    use crate::util::prop;

    fn space() -> SearchSpace {
        threading_space(64, 1024, 64)
    }

    #[test]
    fn request_round_trip() {
        let s = space();
        for req in [
            Request::Describe,
            Request::Evaluate(vec![2, 10, 128, 30, 20]),
            Request::Shutdown,
        ] {
            let line = encode_request(&req, &s);
            assert_eq!(decode_request(&line, &s).unwrap(), req, "line: {line}");
        }
    }

    #[test]
    fn response_round_trip() {
        let s = space();
        for resp in [
            Response::Target { description: "sim:X".into() },
            Response::Result { value: 123.5, config: vec![1, 1, 64, 0, 1] },
            Response::Error { message: "boom \"quoted\"".into() },
            Response::Bye,
        ] {
            let line = encode_response(&resp, &s);
            assert_eq!(decode_response(&line, &s).unwrap(), resp, "line: {line}");
        }
    }

    #[test]
    fn rejects_garbage() {
        let s = space();
        assert!(decode_request("not json", &s).is_err());
        assert!(decode_request(r#"{"type":"nope"}"#, &s).is_err());
        assert!(decode_response(r#"{"type":"result"}"#, &s).is_err());
    }

    #[test]
    fn prop_evaluate_round_trip_any_config() {
        let s = space();
        prop::check("proto evaluate round trip", 100, |rng| {
            let cfg = s.random(rng);
            let line = encode_request(&Request::Evaluate(cfg.clone()), &s);
            assert_eq!(decode_request(&line, &s).unwrap(), Request::Evaluate(cfg));
        });
    }
}
