//! Bench: every hot path in the stack, for the §Perf pass (DESIGN.md §9):
//!
//!   - simulator evaluation (L3 substrate)
//!   - native GP fit+score vs the AOT HLO GP via PJRT (L2+L1), by history size
//!   - shared-surrogate tell enqueue + ask under teller contention
//!   - observability plane: instrumented tell vs the disabled-bus gate
//!   - sharded scaling tier: routed tell + blended ask at n=20k, vs the
//!     exact engine's extrapolated O(n²) wall
//!   - surrogate service: factor-delta export/encode + remote tell round trip
//!   - persistence plane: snapshot write + cold WAL replay
//!   - BO / GA / NMS propose cost
//!   - candidate generation + argmax
//!   - host/target TCP round trip
//!   - history bookkeeping & JSONL encode
//!
//!     cargo bench --bench hot_paths

use tftune::algorithms::{Algorithm, BayesOpt, Tuner};
use tftune::evaluator::{Evaluator, RemoteEvaluator, SimEvaluator};
use tftune::gp::{
    GpHyper, IncrementalGp, NativeGp, NativeSurrogate, ScoreWorkspace, SharedSurrogate,
    ShardedGp, Surrogate,
};
use tftune::history::{random_history, Measurement};
use tftune::runtime::GpSurrogate;
use tftune::server::TargetServer;
use tftune::sim::{ModelId, SimWorkload};
use tftune::util::bench::{BenchResult, Bencher};
use tftune::util::{Json, Rng};

fn gp_problem(rng: &mut Rng, n: usize, c: usize) -> (Vec<Vec<f64>>, Vec<f64>, Vec<Vec<f64>>) {
    let x: Vec<Vec<f64>> = (0..n).map(|_| (0..5).map(|_| rng.f64()).collect()).collect();
    let y: Vec<f64> = x.iter().map(|p| p[0] - p[1]).collect();
    let cand: Vec<Vec<f64>> = (0..c).map(|_| (0..5).map(|_| rng.f64()).collect()).collect();
    (x, y, cand)
}

fn main() -> anyhow::Result<()> {
    // `cargo bench --bench hot_paths -- --smoke`: a short CI-sized run of
    // only the scoring-engine panel. Leaves BENCH_gp.json untouched — the
    // committed file is the cross-commit regression baseline, and a smoke
    // run's numbers are too noisy to publish.
    if std::env::args().skip(1).any(|a| a == "--smoke") {
        let mut b = Bencher::new(100, 400);
        let mut rng = Rng::new(0xBEEF);
        println!("== scoring engine smoke, n=512 / 512 candidates ==");
        bench_scoring_engine(&mut b, &mut rng);
        println!("\nsmoke run complete (scoring engine only; BENCH_gp.json untouched)");
        return Ok(());
    }

    let mut b = Bencher::new(300, 1500);
    let mut rng = Rng::new(0xBEEF);

    println!("== L3 simulator ==");
    let model = ModelId::Resnet50Int8;
    let space = model.space();
    let w = SimWorkload::noiseless(model);
    let cfgs: Vec<_> = (0..128).map(|_| space.random(&mut rng)).collect();
    let mut i = 0;
    b.bench("sim/true_throughput(resnet50-int8)", || {
        i = (i + 1) % cfgs.len();
        w.true_throughput(&cfgs[i])
    });

    println!("\n== incremental surrogate subsystem, n=64 / 512 candidates ==");
    let (r_scratch, r_append, r_score, r_fit_only, r_score_mo, speedup) = {
        let n = 64;
        let c = 512;
        let (x, y, cand) = gp_problem(&mut rng, n, c);
        let hyper = GpHyper::default();

        // Baseline: the pre-refactor path — refit the exact GP from
        // scratch (O(n³) + allocations) and score per candidate.
        let mut scratch = NativeSurrogate;
        let r_scratch = b.bench("gp/gp_fit_scratch n=64 c=512", || {
            scratch.fit_score(&x, &y, &cand, hyper, 1.5, 1.0).unwrap().gain[0]
        });

        // Incremental tell path: rank-1 Cholesky append of the 64th point
        // onto a persistent 63-point factor (extend+retract keeps the
        // model at steady state between iterations).
        let mut inc = IncrementalGp::new(hyper);
        for (xi, &yi) in x.iter().take(n - 1).zip(&y) {
            assert!(inc.push(xi, yi));
        }
        let x_last = x[n - 1].clone();
        let r_append = b.bench("gp/gp_append_rank1 n=63->64", || {
            assert!(inc.extend_fantasy(&x_last, 0.0));
            inc.retract_fantasies();
            inc.total()
        });

        // Incremental ask path: blocked zero-allocation scoring of the
        // full candidate pool on the persistent 64-point factor.
        assert!(inc.push(&x_last, y[n - 1]));
        let cand_flat: Vec<f64> = cand.iter().flatten().copied().collect();
        let mut ws = ScoreWorkspace::default();
        let r_score = b.bench("gp/score_512_candidates n=64", || {
            inc.score_into(&cand_flat, c, 1.5, 1.0, &mut ws);
            ws.gain[0]
        });

        // Sanity on the refit-only component for context.
        let r_fit_only = b.bench("gp/fit_only_scratch n=64", || {
            NativeGp::fit(&x, &y, hyper).unwrap().predict(&cand[..1]).mean[0]
        });

        // Multi-objective panel pass: K=2 target columns over the SAME
        // factor — one panel build + variance solve, two α solves/mean
        // accumulations. The whole point of the design is that this
        // costs far less than two single-objective passes.
        let y2: Vec<f64> = x.iter().map(|p| p[2] - 0.5 * p[3]).collect();
        let mut ws_mo = ScoreWorkspace::default();
        let r_score_mo = b.bench("gp/score_multiobj_k2_512 n=64", || {
            let targets: [&[f64]; 2] = [&y, &y2];
            inc.score_multi_into(&cand_flat, c, &targets, &mut ws_mo);
            ws_mo.mean_obj[0]
        });

        let incremental_ns = r_append.mean_ns + r_score.mean_ns;
        let speedup = r_scratch.mean_ns / incremental_ns;
        println!(
            "  incremental append+score {:.1} µs vs scratch refit+score {:.1} µs  ({speedup:.2}x)",
            incremental_ns / 1e3,
            r_scratch.mean_ns / 1e3,
        );
        println!(
            "  K=2 panel pass {:.1} µs vs 2x single-objective {:.1} µs",
            r_score_mo.mean_ns / 1e3,
            2.0 * r_score.mean_ns / 1e3,
        );
        (r_scratch, r_append, r_score, r_fit_only, r_score_mo, speedup)
    };

    println!("\n== scoring engine, n=512 / 512 candidates ==");
    let [r_512, r_512_naive, r_512_par, r_512_f32, r_512_mo] =
        bench_scoring_engine(&mut b, &mut rng);

    println!("\n== shared surrogate: contended tell/ask ==");
    let (r_shared_tell, r_shared_ask) = {
        use std::sync::atomic::{AtomicBool, Ordering};
        let hyper = GpHyper::default();

        // tell side: steady-state cost of reporting a measurement —
        // enqueue plus the amortized reclaim of queue rows (the periodic
        // reset). Row reclaim is per-row work a real run pays at drain
        // time, so it belongs in the per-tell price.
        let shared = SharedSurrogate::new(hyper);
        let row: Vec<f64> = (0..5).map(|_| rng.f64()).collect();
        let mut told = 0u64;
        let r_tell = b.bench("gp/shared_tell_enqueue", || {
            shared.tell(row.clone(), 1.0);
            told += 1;
            if told % 4096 == 0 {
                shared.reset();
            }
            told
        });

        // ask side under contention: three teller threads stream
        // observations in while the ask loop drains, (re)builds the
        // windowed factor and block-scores 512 candidates.
        let shared = SharedSurrogate::new(hyper);
        {
            let mut seed_rng = Rng::new(0xC0FFEE);
            for _ in 0..64 {
                let x: Vec<f64> = (0..5).map(|_| seed_rng.f64()).collect();
                shared.tell(x, seed_rng.f64());
            }
        }
        let stop = AtomicBool::new(false);
        let r_ask = std::thread::scope(|scope| {
            for t in 0..3u64 {
                let handle = shared.clone();
                let stop = &stop;
                scope.spawn(move || {
                    let mut trng = Rng::new(0xFEED + t);
                    while !stop.load(Ordering::Relaxed) {
                        let x: Vec<f64> = (0..5).map(|_| trng.f64()).collect();
                        handle.tell(x, trng.f64());
                        // paced like a fast evaluator, not a spin loop
                        std::thread::sleep(std::time::Duration::from_micros(50));
                    }
                });
            }
            let cand_flat: Vec<f64> = (0..512 * 5).map(|_| rng.f64()).collect();
            let mut ws = ScoreWorkspace::default();
            let mut y_buf: Vec<f64> = Vec::new();
            let r = b.bench("gp/shared_ask_contended n<=64 c=512", || {
                let mut g = shared.lock();
                if g.len() < 2 {
                    return f64::NAN; // store just reset; refills next pass
                }
                let idx = g.conditioning_set();
                if !g.sync(&idx) {
                    return f64::NAN;
                }
                y_buf.clear();
                y_buf.extend(idx.iter().map(|&i| g.y(i)));
                g.set_targets(&y_buf);
                g.score_into(&cand_flat, 512, 1.5, 0.0, &mut ws);
                drop(g);
                if shared.len() > 2048 {
                    shared.reset(); // keep conditioning-set selection bounded
                }
                ws.gain[0]
            });
            stop.store(true, Ordering::Relaxed);
            r
        });
        (r_tell, r_ask)
    };

    println!("\n== observability plane: event emit on the tell path ==");
    let (r_event_tell, r_event_disabled) = {
        use tftune::obs::{CountingSink, Event, EventBus};
        let hyper = GpHyper::default();

        // event_emit_tell: the shared-surrogate tell with a live event
        // bus (counting sink attached) — the instrumented per-tell
        // price: enqueue plus one seq allocation and one non-blocking
        // try_send to the collector. Compare against shared_tell_enqueue
        // to read off what instrumentation costs when someone watches.
        let bus = EventBus::new();
        let sink = CountingSink::default();
        bus.attach(Box::new(sink.clone()));
        let shared = SharedSurrogate::new(hyper);
        shared.set_event_source(bus.source("surrogate"));
        let row: Vec<f64> = (0..5).map(|_| rng.f64()).collect();
        let mut told = 0u64;
        let r_tell = b.bench("gp/event_emit_tell", || {
            shared.tell(row.clone(), 1.0);
            told += 1;
            if told % 4096 == 0 {
                shared.reset();
            }
            told
        });
        bus.flush();

        // event_emit_disabled: the emit call itself on a bus with no
        // sink. The gate is one relaxed load, so this must stay ~0 —
        // a run that never asked for observability pays nothing.
        let idle = EventBus::new();
        let src = idle.source("surrogate");
        let mut pending = 0usize;
        let r_disabled = b.bench("gp/event_emit_disabled", || {
            pending += 1;
            src.emit(Event::SurrogateTell { pending });
            pending
        });
        println!(
            "  instrumented tell {:.1} ns vs disabled emit {:.1} ns (sink saw {} records)",
            r_tell.mean_ns,
            r_disabled.mean_ns,
            sink.seen.load(std::sync::atomic::Ordering::Relaxed),
        );
        (r_tell, r_disabled)
    };

    println!("\n== surrogate service: delta export + remote tell round trip ==");
    let (r_sync_delta, r_chunked, r_quantised, r_remote_tell, r_multiobj_tell) = {
        use tftune::server::proto::{
            encode_surrogate_response, SurrogateResponse,
        };
        use tftune::server::TargetServer;
        use tftune::util::linalg::packed_len;

        // surrogate_sync_delta: the service-side cost of a Δn=4 catch-up
        // at n=64 — drain check, suffix slice, wire encode. This is what
        // every replica ask pays on the server.
        let hyper = GpHyper::default();
        let authority = SharedSurrogate::new(hyper);
        let mut seed_rng = Rng::new(0xDE17A);
        for _ in 0..64 {
            let x: Vec<f64> = (0..5).map(|_| seed_rng.f64()).collect();
            authority.tell(x, seed_rng.f64());
        }
        drop(authority.lock()); // drain + eager factor to n=64
        let r_sync = b.bench("gp/surrogate_sync_delta dn=4 n=64", || {
            let d = authority.export_delta(60).unwrap();
            encode_surrogate_response(&SurrogateResponse::FactorDelta {
                delta: d,
                pending: 0,
                quantised: false,
            })
            .len()
        });

        // The protocol-v4 catch-up encodings over a 512-row authority
        // (ISSUE 8): one bounded 64-row chunk out of a cold 512-row
        // catch-up (the server-side export + truncate + encode a
        // `max_rows` sync pays per response), and the full quantised
        // transfer (f32 mantissa + exact XOR residual per factor value).
        let big = SharedSurrogate::new(hyper);
        let mut big_rng = Rng::new(0xB16F);
        for _ in 0..512 {
            let x: Vec<f64> = (0..5).map(|_| big_rng.f64()).collect();
            big.tell(x, big_rng.f64());
        }
        drop(big.lock()); // drain + eager factor to n=512
        let r_chunked = b.bench("gp/sync_factor_chunked_512 k=64", || {
            let mut d = big.export_delta(0).unwrap();
            let k = 64usize;
            let pending = d.rows.len() - k;
            d.rows.truncate(k);
            d.extras.truncate(k);
            d.total_n = k;
            if let Some(f) = &mut d.factor {
                f.truncate(packed_len(k));
            }
            encode_surrogate_response(&SurrogateResponse::FactorDelta {
                delta: d,
                pending,
                quantised: false,
            })
            .len()
        });
        let r_quantised = b.bench("gp/sync_factor_quantised_512", || {
            let d = big.export_delta(0).unwrap();
            encode_surrogate_response(&SurrogateResponse::FactorDelta {
                delta: d,
                pending: 0,
                quantised: true,
            })
            .len()
        });

        // remote_tell_roundtrip: one tell-obs line plus the sync that
        // makes it visible in the replica's mirror — the full
        // cross-process tell→conditioned path over loopback TCP.
        let (server, _factor) = TargetServer::bind_surrogate_only("127.0.0.1:0", hyper)?;
        let (addr, handle) = server.spawn()?;
        let replica = tftune::gp::RemoteSurrogate::connect(&addr.to_string())?;
        use tftune::gp::SurrogateHandle;
        let row: Vec<f64> = (0..5).map(|_| rng.f64()).collect();
        let r_tell_rt = b.bench("gp/remote_tell_roundtrip", || {
            replica.tell(row.clone(), 1.0);
            let g = replica.lock(); // sync-factor round trip + import
            g.len()
        });

        // The K=2 variant: a tell carrying a secondary objective column
        // plus the sync that mirrors it — the full multi-objective
        // tell→conditioned path over loopback TCP (protocol v3 "ys").
        let r_tell_mo = b.bench("gp/multiobj_tell_roundtrip", || {
            replica.tell_multi(row.clone(), vec![1.0, -4.0]);
            let g = replica.lock();
            g.len()
        });
        // shut the service down via the evaluate plane
        {
            use std::io::Write;
            let space = tftune::space::threading_space(64, 1024, 64);
            let mut s = std::net::TcpStream::connect(addr)?;
            writeln!(
                s,
                "{}",
                tftune::server::proto::encode_request(
                    &tftune::server::proto::Request::Shutdown,
                    &space
                )
            )?;
        }
        let _ = handle.join();
        (r_sync, r_chunked, r_quantised, r_tell_rt, r_tell_mo)
    };

    println!("\n== persistence plane: snapshot write + WAL replay, n=512 ==");
    let (r_snapshot_write, r_wal_replay) = {
        use tftune::persist::{self, PersistOptions};
        let hyper = GpHyper::default();

        // snapshot_write_512: one checkpoint of a 512-row store — export
        // under the model lock, canonical serialize, checksum, atomic
        // temp+rename publish. The daemon's --snapshot-every steady-state
        // cost, and the price of truncating the replayable WAL suffix.
        let dir_snap = std::env::temp_dir().join("tftune_bench_snapshot");
        let _ = std::fs::remove_dir_all(&dir_snap);
        let shared = SharedSurrogate::new(hyper);
        let mut seed_rng = Rng::new(0x5EED);
        for _ in 0..512 {
            let x: Vec<f64> = (0..5).map(|_| seed_rng.f64()).collect();
            shared.tell(x, seed_rng.f64());
        }
        drop(shared.lock()); // drain + eager factor to n=512
        let r_snap = b.bench("gp/snapshot_write_512", || {
            persist::write_snapshot(&shared, &dir_snap).unwrap()
        });

        // wal_replay_512: cold recovery from a WAL-only state dir (no
        // snapshot) — parse 512 records and re-run the drain path's
        // rank-1 appends. The worst case a crash can leave behind;
        // snapshots exist to amortise exactly this.
        let dir_wal = std::env::temp_dir().join("tftune_bench_wal");
        let _ = std::fs::remove_dir_all(&dir_wal);
        {
            let source = SharedSurrogate::new(hyper);
            let opts = PersistOptions { fsync_every: 64 };
            let p = persist::attach(&source, &dir_wal, opts)?;
            let mut wal_rng = Rng::new(0x317A);
            for _ in 0..512 {
                let x: Vec<f64> = (0..5).map(|_| wal_rng.f64()).collect();
                source.tell(x, wal_rng.f64());
            }
            drop(source.lock());
            p.sync()?;
        }
        let r_replay = b.bench("gp/wal_replay_512", || {
            persist::recover(&dir_wal, hyper).unwrap().surrogate.len()
        });
        let _ = std::fs::remove_dir_all(&dir_snap);
        let _ = std::fs::remove_dir_all(&dir_wal);
        (r_snap, r_replay)
    };

    println!("\n== sharded scaling tier: n=20k at cap 512 vs the exact wall ==");
    let (r_sharded_tell, r_sharded_ask, r_exact_tell) = {
        // The headline: a 20 000-row history, far past anything the flat
        // O(n²)-per-tell engine can sustain. Build cost (including every
        // KD split along the way) is paid once here; the benches measure
        // the steady state a long campaign lives in.
        let mut sharded = ShardedGp::new(GpHyper::default(), 512, 2);
        let mut srng = Rng::new(0x54A2D);
        let build_start = std::time::Instant::now();
        for _ in 0..20_000 {
            let x: Vec<f64> = (0..5).map(|_| srng.f64()).collect();
            let y = x[0] - x[1];
            assert!(sharded.push(&x, y), "random shard factors must stay positive definite");
        }
        println!(
            "  built 20k rows in {:.2}s ({} shards, largest {} rows)",
            build_start.elapsed().as_secs_f64(),
            sharded.num_shards(),
            sharded.max_shard_rows()
        );

        // Routed rank-1 append at n=20k: extend+retract keeps the model
        // at steady state between iterations (same shape as
        // gp_append_rank1 above), so this is the pure per-tell price.
        let x_probe: Vec<f64> = (0..5).map(|_| srng.f64()).collect();
        let r_tell = b.bench("gp/sharded_tell_n20k cap=512", || {
            assert!(sharded.extend_fantasy(&x_probe, 0.0));
            sharded.retract_fantasies();
            sharded.len()
        });

        // Blended 512-candidate ask over the whole 20k-row ensemble.
        let cand_flat: Vec<f64> = (0..512 * 5).map(|_| srng.f64()).collect();
        let mut ws = ScoreWorkspace::default();
        let r_ask = b.bench("gp/sharded_ask_512_n20k blend=2", || {
            sharded.score_into(&cand_flat, 512, 1.5, 0.0, &mut ws);
            ws.gain[0]
        });

        // The exact comparison point. A flat factor at n=20k is minutes
        // to build and ~1.6 GB of triangle, so the exact append is
        // measured at n=2048 and extrapolated by the O(n²) law the
        // incremental engine provably follows (ISSUE 2).
        let mut exact = IncrementalGp::new(GpHyper::default());
        let mut erng = Rng::new(0xE6AC7);
        for _ in 0..2048 {
            let x: Vec<f64> = (0..5).map(|_| erng.f64()).collect();
            assert!(exact.push(&x, x[0] - x[1]));
        }
        let r_exact = b.bench("gp/exact_tell_n2048", || {
            assert!(exact.extend_fantasy(&x_probe, 0.0));
            exact.retract_fantasies();
            exact.total()
        });
        let scale = (20_000.0 / 2048.0) * (20_000.0 / 2048.0);
        println!(
            "  sharded tell {:.1} µs at n=20k vs exact append {:.1} µs at n=2048 \
             (≈{:.0} µs extrapolated to n=20k: {:.0}× the sharded tell; \
             acceptance floor is 50×)",
            r_tell.mean_ns / 1e3,
            r_exact.mean_ns / 1e3,
            r_exact.mean_ns * scale / 1e3,
            r_exact.mean_ns * scale / r_tell.mean_ns,
        );
        (r_tell, r_ask, r_exact)
    };

    write_gp_bench_json(
        &[
            &r_scratch,
            &r_append,
            &r_score,
            &r_fit_only,
            &r_score_mo,
            &r_shared_tell,
            &r_shared_ask,
            &r_event_tell,
            &r_event_disabled,
            &r_sync_delta,
            &r_chunked,
            &r_quantised,
            &r_remote_tell,
            &r_multiobj_tell,
            &r_snapshot_write,
            &r_wal_replay,
            &r_512,
            &r_512_naive,
            &r_512_par,
            &r_512_f32,
            &r_512_mo,
            &r_sharded_tell,
            &r_sharded_ask,
            &r_exact_tell,
        ],
        64,
        512,
        speedup,
    )?;

    println!("\n== GP surrogate: native vs AOT HLO (PJRT), 512 candidates ==");
    for n in [8usize, 32, 64] {
        let (x, y, cand) = gp_problem(&mut rng, n, 512);
        let mut native = NativeSurrogate;
        b.bench(&format!("gp-native/fit_score n={n}"), || {
            native.fit_score(&x, &y, &cand, GpHyper::default(), 1.5, 1.0).unwrap().gain[0]
        });
        match GpSurrogate::open_default() {
            Ok(mut hlo) => {
                b.bench(&format!("gp-hlo-pjrt/fit_score n={n}"), || {
                    hlo.fit_score(&x, &y, &cand, GpHyper::default(), 1.5, 1.0).unwrap().gain[0]
                });
            }
            Err(e) => println!("  (skipping HLO surrogate: {e})"),
        }
    }

    println!("\n== engine ask/tell ==");
    for alg in [Algorithm::Bo, Algorithm::Ga, Algorithm::Nms, Algorithm::Random] {
        let mut tuner = alg.build(&space, 1);
        let mut eval = SimEvaluator::new(model, 1);
        b.bench(&format!("engine/{}", alg.name()), || {
            let trial = tuner.ask(1).pop().unwrap();
            let v = eval.evaluate(&trial.config).unwrap();
            tuner.tell(trial.id, &Measurement::new(v));
            v
        });
    }
    if let Ok(hlo) = GpSurrogate::open_default() {
        let mut bo = BayesOpt::with_surrogate(space.clone(), 2, hlo);
        let mut eval = SimEvaluator::new(model, 2);
        b.bench("engine/bo-hlo-surrogate", || {
            let trial = bo.ask(1).pop().unwrap();
            let v = eval.evaluate(&trial.config).unwrap();
            bo.tell(trial.id, &Measurement::new(v));
            v
        });
    }

    println!("\n== host/target protocol round trip (localhost TCP) ==");
    {
        let server = TargetServer::bind(
            "127.0.0.1:0",
            space.clone(),
            Box::new(SimEvaluator::new(model, 3)),
        )?;
        let (addr, handle) = server.spawn()?;
        let mut remote = RemoteEvaluator::connect(&addr.to_string(), space.clone())?;
        let cfg = space.random(&mut rng);
        b.bench("protocol/evaluate-round-trip", || remote.evaluate(&cfg).unwrap());
        remote.shutdown()?;
        let _ = handle.join();
    }

    println!("\n== bookkeeping ==");
    let h = random_history(&space, 50, 1);
    b.bench("history/best_curve(50)", || h.best_curve().len());
    b.bench("history/to_jsonl(50)", || h.to_jsonl(&space).len());
    b.bench("space/random+to_unit+from_unit", || {
        let c = space.random(&mut rng);
        let u = space.to_unit(&c);
        space.from_unit(&u)[0]
    });

    println!("\ndone; see EXPERIMENTS.md §Perf for targets and history.");
    Ok(())
}

/// The n=512 scoring-engine panel (ISSUE 7): the serial blocked baseline,
/// the unblocked kernels (`BlockSpec::naive`), the 4-thread fixed
/// partition, the f32 ranking tier, and the K=2 multi-objective panel —
/// all over the same 512-point factor and 512-candidate pool. The
/// `--smoke` flag runs only this section.
fn bench_scoring_engine(b: &mut Bencher, rng: &mut Rng) -> [BenchResult; 5] {
    use tftune::gp::{BlockSpec, ScoreTier};
    let n = 512;
    let c = 512;
    let (x, y, cand) = gp_problem(rng, n, c);
    let mut inc = IncrementalGp::new(GpHyper::default());
    for (xi, &yi) in x.iter().zip(&y) {
        assert!(inc.push(xi, yi), "512-point factor must stay positive definite");
    }
    let cand_flat: Vec<f64> = cand.iter().flatten().copied().collect();

    // Serial f64 blocked scoring: the committed baseline the parallel
    // acceptance gate (>=2x at 4 threads) is measured against.
    let mut ws = ScoreWorkspace::default();
    let r_serial = b.bench("gp/score_512_candidates_n512 serial f64", || {
        inc.score_into(&cand_flat, c, 1.5, 1.0, &mut ws);
        ws.gain[0]
    });

    // Unblocked kernels: what cache tiling buys at this panel size.
    inc.set_block_spec(BlockSpec::naive());
    let r_naive = b.bench("gp/score_512_naive_n512 serial f64", || {
        inc.score_into(&cand_flat, c, 1.5, 1.0, &mut ws);
        ws.gain[0]
    });
    inc.set_block_spec(BlockSpec::default());

    // 4-thread fixed-partition panel: bit-identical to serial by
    // construction (pinned in rust/tests/scoring_engine.rs).
    inc.set_score_threads(4);
    let r_par = b.bench("gp/score_512_parallel_t4 f64", || {
        inc.score_into(&cand_flat, c, 1.5, 1.0, &mut ws);
        ws.gain[0]
    });

    // f32 ranking tier on top of the 4-thread partition.
    inc.set_score_tier(ScoreTier::F32);
    let r_f32 = b.bench("gp/score_512_f32 t4", || {
        inc.score_into(&cand_flat, c, 1.5, 1.0, &mut ws);
        ws.gain[0]
    });
    inc.set_score_tier(ScoreTier::F64);
    inc.set_score_threads(1);

    // K=2 multi-objective panel through the same engine.
    let y2: Vec<f64> = x.iter().map(|p| p[2] - 0.5 * p[3]).collect();
    let mut ws_mo = ScoreWorkspace::default();
    let r_mo = b.bench("gp/score_multiobj_k2_n512 serial f64", || {
        let targets: [&[f64]; 2] = [&y, &y2];
        inc.score_multi_into(&cand_flat, c, &targets, &mut ws_mo);
        ws_mo.mean_obj[0]
    });

    println!(
        "  4-thread panel {:.1} µs vs serial {:.1} µs ({:.2}x); naive blocks {:.1} µs; \
         f32 tier {:.1} µs",
        r_par.mean_ns / 1e3,
        r_serial.mean_ns / 1e3,
        r_serial.mean_ns / r_par.mean_ns,
        r_naive.mean_ns / 1e3,
        r_f32.mean_ns / 1e3,
    );
    [r_serial, r_naive, r_par, r_f32, r_mo]
}

/// Persist the surrogate-subsystem baseline (ISSUE 2 acceptance: the
/// incremental append + blocked scoring must beat the scratch refit at
/// n=64 / 512 candidates; ISSUE 3 adds the contended shared tell/ask
/// pair; ISSUE 4 adds the surrogate-service pair — `surrogate_sync_delta`
/// / `remote_tell_roundtrip`; ISSUE 5 adds the multi-objective pair —
/// `score_multiobj_k2_512` / `multiobj_tell_roundtrip`; ISSUE 6 adds the
/// persistence pair — `snapshot_write_512` / `wal_replay_512`; ISSUE 7
/// adds the scoring-engine panel at n=512 — `score_512_candidates_n512`
/// serial baseline, `score_512_naive_n512` unblocked kernels,
/// `score_512_parallel_t4` 4-thread partition, `score_512_f32` fast tier,
/// `score_multiobj_k2_n512` K=2 panel; ISSUE 8 adds the protocol-v4
/// catch-up pair — `sync_factor_chunked_512` / `sync_factor_quantised_512`;
/// ISSUE 9 adds the sharded scaling tier — `sharded_tell_n20k` /
/// `sharded_ask_512_n20k` at the default cap, with `exact_tell_n2048` as
/// the measured point the O(n²) extrapolation — the wall the tier
/// breaks — is anchored to; ISSUE 10 adds the observability pair —
/// `event_emit_tell` instrumented tell / `event_emit_disabled` the
/// sink-less gate, which must stay ~0).
/// Keys are the bench short names.
/// `"estimated": false` marks the numbers as measured on real hardware —
/// CI's regression guard skips files whose baseline was only estimated.
fn write_gp_bench_json(
    results: &[&BenchResult],
    n: usize,
    c: usize,
    speedup: f64,
) -> anyhow::Result<()> {
    let mut benches = std::collections::BTreeMap::new();
    for r in results {
        let key = r
            .name
            .trim_start_matches("gp/")
            .split_whitespace()
            .next()
            .unwrap_or(&r.name)
            .to_string();
        benches.insert(
            key,
            Json::obj(vec![
                ("mean_ns", Json::from(r.mean_ns)),
                ("median_ns", Json::from(r.median_ns)),
                ("p95_ns", Json::from(r.p95_ns)),
                ("iters", Json::from(r.iters as f64)),
            ]),
        );
    }
    let doc = Json::obj(vec![
        ("n_history", Json::from(n)),
        ("n_candidates", Json::from(c)),
        ("estimated", Json::from(false)),
        ("benches", Json::Obj(benches)),
        ("incremental_vs_scratch_speedup", Json::from(speedup)),
        ("incremental_beats_scratch", Json::from(speedup > 1.0)),
    ]);
    std::fs::write("BENCH_gp.json", format!("{doc}\n"))?;
    println!("  wrote BENCH_gp.json");
    Ok(())
}
