//! Bench: every hot path in the stack, for the §Perf pass (DESIGN.md §9):
//!
//!   - simulator evaluation (L3 substrate)
//!   - native GP fit+score vs the AOT HLO GP via PJRT (L2+L1), by history size
//!   - BO / GA / NMS propose cost
//!   - candidate generation + argmax
//!   - host/target TCP round trip
//!   - history bookkeeping & JSONL encode
//!
//!     cargo bench --bench hot_paths

use tftune::algorithms::{Algorithm, BayesOpt, Tuner};
use tftune::evaluator::{Evaluator, RemoteEvaluator, SimEvaluator};
use tftune::gp::{GpHyper, NativeSurrogate, Surrogate};
use tftune::history::{random_history, Measurement};
use tftune::runtime::GpSurrogate;
use tftune::server::TargetServer;
use tftune::sim::{ModelId, SimWorkload};
use tftune::util::bench::Bencher;
use tftune::util::Rng;

fn gp_problem(rng: &mut Rng, n: usize, c: usize) -> (Vec<Vec<f64>>, Vec<f64>, Vec<Vec<f64>>) {
    let x: Vec<Vec<f64>> = (0..n).map(|_| (0..5).map(|_| rng.f64()).collect()).collect();
    let y: Vec<f64> = x.iter().map(|p| p[0] - p[1]).collect();
    let cand: Vec<Vec<f64>> = (0..c).map(|_| (0..5).map(|_| rng.f64()).collect()).collect();
    (x, y, cand)
}

fn main() -> anyhow::Result<()> {
    let mut b = Bencher::new(300, 1500);
    let mut rng = Rng::new(0xBEEF);

    println!("== L3 simulator ==");
    let model = ModelId::Resnet50Int8;
    let space = model.space();
    let w = SimWorkload::noiseless(model);
    let cfgs: Vec<_> = (0..128).map(|_| space.random(&mut rng)).collect();
    let mut i = 0;
    b.bench("sim/true_throughput(resnet50-int8)", || {
        i = (i + 1) % cfgs.len();
        w.true_throughput(&cfgs[i])
    });

    println!("\n== GP surrogate: native vs AOT HLO (PJRT), 512 candidates ==");
    for n in [8usize, 32, 64] {
        let (x, y, cand) = gp_problem(&mut rng, n, 512);
        let mut native = NativeSurrogate;
        b.bench(&format!("gp-native/fit_score n={n}"), || {
            native.fit_score(&x, &y, &cand, GpHyper::default(), 1.5, 1.0).unwrap().gain[0]
        });
        match GpSurrogate::open_default() {
            Ok(mut hlo) => {
                b.bench(&format!("gp-hlo-pjrt/fit_score n={n}"), || {
                    hlo.fit_score(&x, &y, &cand, GpHyper::default(), 1.5, 1.0).unwrap().gain[0]
                });
            }
            Err(e) => println!("  (skipping HLO surrogate: {e})"),
        }
    }

    println!("\n== engine ask/tell ==");
    for alg in [Algorithm::Bo, Algorithm::Ga, Algorithm::Nms, Algorithm::Random] {
        let mut tuner = alg.build(&space, 1);
        let mut eval = SimEvaluator::new(model, 1);
        b.bench(&format!("engine/{}", alg.name()), || {
            let trial = tuner.ask(1).pop().unwrap();
            let v = eval.evaluate(&trial.config).unwrap();
            tuner.tell(trial.id, &Measurement::new(v));
            v
        });
    }
    if let Ok(hlo) = GpSurrogate::open_default() {
        let mut bo = BayesOpt::with_surrogate(space.clone(), 2, hlo);
        let mut eval = SimEvaluator::new(model, 2);
        b.bench("engine/bo-hlo-surrogate", || {
            let trial = bo.ask(1).pop().unwrap();
            let v = eval.evaluate(&trial.config).unwrap();
            bo.tell(trial.id, &Measurement::new(v));
            v
        });
    }

    println!("\n== host/target protocol round trip (localhost TCP) ==");
    {
        let server = TargetServer::bind(
            "127.0.0.1:0",
            space.clone(),
            Box::new(SimEvaluator::new(model, 3)),
        )?;
        let (addr, handle) = server.spawn()?;
        let mut remote = RemoteEvaluator::connect(&addr.to_string(), space.clone())?;
        let cfg = space.random(&mut rng);
        b.bench("protocol/evaluate-round-trip", || remote.evaluate(&cfg).unwrap());
        remote.shutdown()?;
        let _ = handle.join();
    }

    println!("\n== bookkeeping ==");
    let h = random_history(&space, 50, 1);
    b.bench("history/best_curve(50)", || h.best_curve().len());
    b.bench("history/to_jsonl(50)", || h.to_jsonl(&space).len());
    b.bench("space/random+to_unit+from_unit", || {
        let c = space.random(&mut rng);
        let u = space.to_unit(&c);
        space.from_unit(&u)[0]
    });

    println!("\ndone; see EXPERIMENTS.md §Perf for targets and history.");
    Ok(())
}
