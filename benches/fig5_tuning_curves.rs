//! Bench: regenerate Fig. 5 (tuning curves for 6 models × {BO, GA, NMS})
//! and report per-algorithm engine overhead (time per tuning iteration,
//! excluding the system under test — on the real testbed each evaluation
//! costs ~1 minute, so engine overhead must be negligible).
//!
//!     cargo bench --bench fig5_tuning_curves

use tftune::algorithms::{Algorithm, Tuner};
use tftune::config::SurrogateKind;
use tftune::evaluator::SimEvaluator;
use tftune::figures::{fig5, OUT_DIR};
use tftune::sim::ModelId;
use tftune::util::bench::Bencher;

fn main() -> anyhow::Result<()> {
    let iters = 50;
    let seeds = [0u64, 1, 2];

    println!(
        "== Fig. 5 regeneration: 6 models x 3 algorithms x {} seeds x {iters} iters ==",
        seeds.len()
    );
    let t0 = std::time::Instant::now();
    let curves = fig5::run_figure(iters, &seeds, SurrogateKind::Native, OUT_DIR.as_ref())?;
    let wall = t0.elapsed().as_secs_f64();
    fig5::print_summary(&curves);
    println!("\nregenerated in {wall:.2}s; CSVs under {OUT_DIR}/");

    // Engine overhead per iteration (propose+observe with sim evaluation).
    println!("\n== engine overhead per tuning iteration (ResNet50-INT8) ==");
    let model = ModelId::Resnet50Int8;
    let space = model.space();
    let mut b = Bencher::new(200, 1200);
    for alg in [Algorithm::Bo, Algorithm::Ga, Algorithm::Nms, Algorithm::Random] {
        let mut tuner = alg.build(&space, 5);
        let mut eval = SimEvaluator::new(model, 5);
        use tftune::evaluator::Evaluator;
        b.bench(&format!("iteration/{}", alg.name()), || {
            let trial = tuner.ask(1).pop().unwrap();
            let v = eval.evaluate(&trial.config).unwrap();
            tuner.tell(trial.id, &tftune::history::Measurement::new(v));
            v
        });
    }
    println!("\n(paper context: a real evaluation is ~60 s; all engines are <1e-3 of that)");
    Ok(())
}
