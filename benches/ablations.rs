//! Ablation benches for the design choices DESIGN.md calls out:
//!
//!   A1  NMS restarts on/off (TensorTuner-style vs modernised)
//!   A2  BO acquisition optimism alpha (pure exploit -> pure explore)
//!   A3  BO candidate-pool size
//!   A4  extension baselines (SA, coordinate descent) vs the paper's three
//!   A5  measurement-noise sensitivity of each algorithm
//!
//! Each table reports best-found throughput (median over seeds) after the
//! paper's 50-iteration budget on ResNet50-INT8 + BERT-FP32.
//!
//!     cargo bench --bench ablations

use tftune::algorithms::{Algorithm, BayesOpt, NelderMead, Tuner};
use tftune::evaluator::{tune, SimEvaluator};
use tftune::figures::print_table;
use tftune::sim::ModelId;
use tftune::util::stats;

const ITERS: usize = 50;
const SEEDS: [u64; 5] = [0, 1, 2, 3, 4];

fn run_with(mk: impl Fn(u64) -> Box<dyn Tuner>, model: ModelId, sigma: f64) -> f64 {
    let bests: Vec<f64> = SEEDS
        .iter()
        .map(|&seed| {
            let mut t = mk(seed);
            let mut eval = SimEvaluator::with_sigma(model, seed, sigma);
            let h = tune(t.as_mut(), &mut eval, ITERS).unwrap();
            h.best().unwrap().value
        })
        .collect();
    stats::median(&bests)
}

fn main() -> anyhow::Result<()> {
    let models = [ModelId::Resnet50Int8, ModelId::BertFp32];
    let sigma = tftune::sim::noise::DEFAULT_SIGMA;

    // A1: NMS restarts.
    let mut rows = Vec::new();
    for model in models {
        let space = model.space();
        let plain = run_with(
            |s| Box::new(NelderMead::new(space.clone(), s)),
            model,
            sigma,
        );
        let restart = run_with(
            |s| Box::new(NelderMead::new(space.clone(), s).with_restarts(true)),
            model,
            sigma,
        );
        rows.push(vec![
            model.name().to_string(),
            format!("{plain:.1}"),
            format!("{restart:.1}"),
            format!("{:+.2}%", (restart / plain - 1.0) * 100.0),
        ]);
    }
    print_table(
        "A1 — NMS restart ablation (best ex/s, median over seeds)",
        &["model", "TensorTuner-style (no restart)", "with restarts", "delta"],
        &rows,
    );

    // A2: BO acquisition alpha. Uses the public with_acq_alpha knob.
    let mut rows = Vec::new();
    for model in models {
        let space = model.space();
        let mut row = vec![model.name().to_string()];
        for alpha in [0.0, 0.5, 1.5, 3.0] {
            let v = run_with(
                |s| Box::new(BayesOpt::new(space.clone(), s).with_acq_alpha(alpha)),
                model,
                sigma,
            );
            row.push(format!("{v:.1}"));
        }
        rows.push(row);
    }
    print_table(
        "A2 — BO acquisition optimism (best ex/s by alpha)",
        &["model", "alpha=0 (exploit)", "alpha=0.5", "alpha=1.5 (default)", "alpha=3 (explore)"],
        &rows,
    );

    // A3: BO candidate-pool size.
    let mut rows = Vec::new();
    for model in models {
        let space = model.space();
        let mut row = vec![model.name().to_string()];
        for cands in [32usize, 128, 512] {
            let v = run_with(
                |s| Box::new(BayesOpt::new(space.clone(), s).with_candidates(cands)),
                model,
                sigma,
            );
            row.push(format!("{v:.1}"));
        }
        rows.push(row);
    }
    print_table(
        "A3 — BO candidate-pool size (best ex/s)",
        &["model", "32", "128", "512 (default)"],
        &rows,
    );

    // A4: extension baselines vs the paper's algorithms.
    let mut rows = Vec::new();
    for model in models {
        let space = model.space();
        let mut row = vec![model.name().to_string()];
        for alg in [Algorithm::Bo, Algorithm::Ga, Algorithm::Nms, Algorithm::Sa, Algorithm::Coord, Algorithm::Random] {
            let v = run_with(|s| alg.build(&space, s), model, sigma);
            row.push(format!("{v:.1}"));
        }
        rows.push(row);
    }
    print_table(
        "A4 — extension baselines (best ex/s, median over seeds)",
        &["model", "BO", "GA", "NMS", "SA", "CoordDesc", "Random"],
        &rows,
    );

    // A5: noise sensitivity.
    let mut rows = Vec::new();
    for model in [ModelId::Resnet50Int8] {
        let space = model.space();
        for alg in Algorithm::all_paper() {
            let mut row = vec![format!("{} / {}", model.name(), alg.name())];
            for s in [0.0, 0.015, 0.05] {
                let v = run_with(|seed| alg.build(&space, seed), model, s);
                row.push(format!("{v:.1}"));
            }
            rows.push(row);
        }
    }
    print_table(
        "A5 — measurement-noise sensitivity (best ex/s by noise sigma)",
        &["model / algorithm", "sigma=0", "sigma=1.5% (paper-ish)", "sigma=5%"],
        &rows,
    );

    Ok(())
}
