//! Bench: regenerate Fig. 6 (exhaustive 5-parameter sweep of
//! ResNet50-INT8), validate the paper's four qualitative observations, and
//! measure simulator evaluation throughput (the substrate's hot path).
//!
//!     cargo bench --bench fig6_exhaustive_sweep

use tftune::figures::{fig6, OUT_DIR};
use tftune::sim::{ModelId, SimWorkload};
use tftune::util::bench::Bencher;
use tftune::util::Rng;

fn main() -> anyhow::Result<()> {
    println!("== Fig. 6 regeneration: coarsened ~50k-point sweep ==");
    let t0 = std::time::Instant::now();
    let points = fig6::run_sweep(ModelId::Resnet50Int8, false);
    let wall = t0.elapsed().as_secs_f64();
    let findings = fig6::analyze(&points);
    fig6::print_findings(&findings);
    println!(
        "\nsweep: {} points in {wall:.2}s ({:.0} evaluations/s)",
        points.len(),
        points.len() as f64 / wall
    );
    fig6::write_csv(&points, OUT_DIR.as_ref())?;

    // Paper-shape assertions, loudly.
    assert!(findings.blocktime0_best, "FAIL: blocktime=0 not the best marginal");
    assert!(
        findings.omp_influence > 5.0 * findings.intra_influence,
        "FAIL: intra_op influence not negligible vs OMP"
    );
    assert!(
        findings.omp_influence > 2.0 * findings.batch_influence,
        "FAIL: batch influence not second-order vs OMP"
    );
    println!("paper observations: blocktime0_best ok, omp >> intra ok, omp >> batch ok");

    // Per-model single-evaluation latency (the L3 §Perf target: <= 10 µs).
    println!("\n== simulator evaluation latency per model ==");
    let mut b = Bencher::new(200, 1000);
    for model in ModelId::all() {
        let w = SimWorkload::noiseless(model);
        let space = model.space();
        let mut rng = Rng::new(1);
        let cfgs: Vec<_> = (0..64).map(|_| space.random(&mut rng)).collect();
        let mut i = 0;
        b.bench(&format!("sim-eval/{}", model.short_name()), || {
            i = (i + 1) % cfgs.len();
            w.true_throughput(&cfgs[i])
        });
    }
    Ok(())
}
