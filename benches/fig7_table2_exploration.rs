//! Bench: regenerate Fig. 7 (pairplot sample data) and Table 2 (sampled vs
//! tunable ranges) for ResNet50-INT8 and BERT-FP32, asserting the paper's
//! exploration-ordering conclusion (BO ~ 100% coverage >> NMS > GA).
//!
//!     cargo bench --bench fig7_table2_exploration

use tftune::algorithms::Algorithm;
use tftune::config::SurrogateKind;
use tftune::figures::{fig7, OUT_DIR};

fn main() -> anyhow::Result<()> {
    println!("== Fig. 7 / Table 2 regeneration: 2 models x 3 algorithms x 50 iters ==");
    let t0 = std::time::Instant::now();
    let samples = fig7::run_samples(50, 0, SurrogateKind::Native)?;
    fig7::write_csv(&samples, OUT_DIR.as_ref())?;
    fig7::print_table2(&samples);
    println!("\nregenerated in {:.2}s; CSVs under {OUT_DIR}/", t0.elapsed().as_secs_f64());

    // Paper-shape assertions: BO ~100% on every model; GA well under half;
    // NMS between the two on average (per-model NMS-vs-GA order can flip
    // on a single seed — the paper reports the tendency, not a theorem).
    let mut nms_sum = 0.0;
    let mut ga_sum = 0.0;
    for model in fig7::models() {
        let bo = fig7::avg_coverage(&samples, model, Algorithm::Bo).unwrap();
        let ga = fig7::avg_coverage(&samples, model, Algorithm::Ga).unwrap();
        let nms = fig7::avg_coverage(&samples, model, Algorithm::Nms).unwrap();
        println!(
            "{:<22} avg coverage: BO {bo:>5.1}%  NMS {nms:>5.1}%  GA {ga:>5.1}%",
            model.name()
        );
        assert!(bo > 90.0, "BO should cover ~100% (got {bo:.1}%)");
        assert!(ga < 65.0, "GA should stay under ~half coverage (got {ga:.1}%)");
        assert!(bo > nms && bo > ga, "BO must out-explore both for {}", model.name());
        nms_sum += nms;
        ga_sum += ga;
    }
    assert!(nms_sum > ga_sum, "NMS should out-explore GA on average");
    println!("paper Table 2 ordering: BO > NMS > GA (on average) ok");
    Ok(())
}
