#!/usr/bin/env python3
"""Bench-baseline regression guard (CI).

Compares the committed BENCH_gp.json against the previous commit's copy
(``git show HEAD^:BENCH_gp.json``) and fails if any shared bench entry's
``mean_ns`` regressed by more than THRESHOLD, or if an entry present in
the previous baseline disappeared — a vanished row usually means a bench
was silently dropped, which is exactly the regression this guard exists
to catch. New entries (no previous measurement) pass. Files marked
``"estimated": true`` — a baseline written without hardware to measure
on — are skipped on either side: estimates are placeholders, not numbers
to gate against.

Exit codes: 0 ok / skipped, 1 regression, 2 malformed input.
"""

from __future__ import annotations

import json
import subprocess
import sys
from pathlib import Path

BENCH_FILE = "BENCH_gp.json"
THRESHOLD = 0.20  # fail when mean_ns grows by more than 20%


def load_current() -> dict | None:
    path = Path(BENCH_FILE)
    if not path.exists():
        return None
    return json.loads(path.read_text())


def load_previous() -> dict | None:
    try:
        out = subprocess.run(
            ["git", "show", f"HEAD^:{BENCH_FILE}"],
            capture_output=True,
            text=True,
            check=True,
        ).stdout
    except subprocess.CalledProcessError:
        # No parent commit, or the file did not exist there.
        return None
    return json.loads(out)


def main() -> int:
    cur = load_current()
    if cur is None:
        print(f"{BENCH_FILE} not committed; nothing to guard")
        return 0
    prev = load_previous()
    if prev is None:
        print(f"no previous {BENCH_FILE} (first baseline); nothing to compare")
        return 0

    for side, doc in (("current", cur), ("previous", prev)):
        if doc.get("estimated", False):
            print(f"{side} {BENCH_FILE} is marked estimated; skipping the guard")
            return 0
        if not isinstance(doc.get("benches"), dict):
            print(f"{side} {BENCH_FILE} has no 'benches' object", file=sys.stderr)
            return 2

    failures = []
    removed = []
    for name, prev_entry in sorted(prev["benches"].items()):
        cur_entry = cur["benches"].get(name)
        if cur_entry is None:
            print(f"  {name}: REMOVED from baseline")
            removed.append(name)
            continue
        try:
            prev_ns = float(prev_entry["mean_ns"])
            cur_ns = float(cur_entry["mean_ns"])
        except (KeyError, TypeError, ValueError):
            print(f"{name}: malformed mean_ns", file=sys.stderr)
            return 2
        if prev_ns <= 0:
            print(f"  {name}: previous mean_ns <= 0, skipped")
            continue
        ratio = cur_ns / prev_ns
        marker = "REGRESSED" if ratio > 1.0 + THRESHOLD else "ok"
        print(f"  {name}: {prev_ns:.0f} ns -> {cur_ns:.0f} ns ({ratio:.2f}x) {marker}")
        if ratio > 1.0 + THRESHOLD:
            failures.append((name, ratio))

    if removed:
        print(
            f"\n{len(removed)} bench entr{'y' if len(removed) == 1 else 'ies'} "
            f"disappeared from {BENCH_FILE} (present in the previous commit):",
            file=sys.stderr,
        )
        for name in removed:
            print(f"  {name}", file=sys.stderr)
    if failures:
        print(
            f"\n{len(failures)} bench entr{'y' if len(failures) == 1 else 'ies'} "
            f"regressed more than {THRESHOLD:.0%} vs the previous commit:",
            file=sys.stderr,
        )
        for name, ratio in failures:
            print(f"  {name}: {ratio:.2f}x", file=sys.stderr)
    if removed or failures:
        return 1
    print("bench baseline within threshold")
    return 0


if __name__ == "__main__":
    sys.exit(main())
