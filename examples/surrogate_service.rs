//! The cross-process surrogate service, end to end in one binary: a
//! daemon hosting the authoritative GP factor, and several "tuner
//! processes" (a `SessionGroup` of BO sessions, each on its own TCP
//! connection — exactly what separate OS processes or hosts would open)
//! conditioning it through `RemoteSurrogate` replicas.
//!
//!     cargo run --release --example surrogate_service [sessions] [iters]
//!
//! The same deployment with real processes:
//!
//!     tftune surrogate-serve --addr 127.0.0.1:7071 &
//!     tftune tune --model resnet50-fp32 --alg bo --seed 1 \
//!         --surrogate-addr 127.0.0.1:7071 &
//!     tftune tune --model resnet50-fp32 --alg bo --seed 2 \
//!         --surrogate-addr 127.0.0.1:7071

use anyhow::Result;
use tftune::evaluator::{sim_pool, Objective};
use tftune::gp::GpHyper;
use tftune::server::TargetServer;
use tftune::session::{Budget, SessionGroup};
use tftune::sim::ModelId;

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let sessions: usize = args.first().map(|s| s.parse()).transpose()?.unwrap_or(3);
    let iters: usize = args.get(1).map(|s| s.parse()).transpose()?.unwrap_or(24);

    let model = ModelId::Resnet50Fp32;
    let space = model.space();

    // The service: one daemon owning the authoritative factor.
    let (server, factor) = TargetServer::bind_surrogate_only("127.0.0.1:0", GpHyper::default())?;
    let (addr, server_handle) = server.spawn()?;
    println!("surrogate service on {addr}");
    println!(
        "{sessions} BO tuners x {iters} evaluations on {}, one served factor\n",
        model.name()
    );

    // The tuners: each session connects its own replica — tells stream to
    // the service, every ask pulls the factor delta (suffix rows only)
    // plus the other tuners' in-flight lease points.
    let seeds: Vec<u64> = (0..sessions as u64).collect();
    let mut group = SessionGroup::remote_shared_bo(
        &space,
        &addr.to_string(),
        &seeds,
        Budget::evaluations(iters),
        |i| {
            sim_pool(
                model,
                2000 + i as u64,
                tftune::sim::noise::DEFAULT_SIGMA,
                Objective::Throughput,
                2, // two evaluator threads per tuner
            )
        },
    )?;

    let t0 = std::time::Instant::now();
    let histories = group.run()?;
    let wall = t0.elapsed().as_secs_f64();

    for (i, h) in histories.iter().enumerate() {
        let best = h.best().expect("non-empty history");
        println!(
            "tuner {i}: best {:>8.1} examples/s over {} trials",
            best.value,
            h.len()
        );
    }
    // Give the last fire-and-forget tells a moment to land, then read the
    // served factor directly through the local handle the service keeps.
    std::thread::sleep(std::time::Duration::from_millis(100));
    println!(
        "\nserved factor conditioned on {} observations in {wall:.2}s wall clock",
        factor.total_observations()
    );

    // Orderly daemon shutdown over the evaluate plane.
    {
        use std::io::Write;
        use tftune::server::proto::{encode_request, Request};
        let mut s = std::net::TcpStream::connect(addr)?;
        writeln!(s, "{}", encode_request(&Request::Shutdown, &space))?;
    }
    let _ = server_handle.join();
    Ok(())
}
