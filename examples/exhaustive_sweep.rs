//! Fig. 6 exhaustive sweep of ResNet50-INT8 across all five parameters —
//! the ~50k-point grid the paper says took "close to a month of CPU time"
//! on the real testbed. On the simulator substrate it takes seconds, which
//! is exactly why the paper needs sample-efficient tuners for the real
//! system (each real evaluation costs ~1 minute).
//!
//!     cargo run --release --example exhaustive_sweep [--fine]

use anyhow::Result;
use tftune::figures::{fig6, OUT_DIR};
use tftune::sim::ModelId;
use tftune::space;

fn main() -> Result<()> {
    let fine = std::env::args().any(|a| a == "--fine");
    let grid = fig6::sweep_space(fine);
    println!(
        "sweeping ResNet50-INT8 over {} grid points ({})",
        grid.size(),
        if fine { "full Table-1 grid" } else { "paper-scale coarsened grid" }
    );

    let t0 = std::time::Instant::now();
    let points = fig6::run_sweep(ModelId::Resnet50Int8, fine);
    let secs = t0.elapsed().as_secs_f64();

    let findings = fig6::analyze(&points);
    fig6::print_findings(&findings);

    // The marginal curves behind the paper's Fig. 6 reading.
    println!("\nOMP_NUM_THREADS marginal (mean throughput):");
    for (v, t) in fig6::marginal(&points, space::OMP_THREADS).iter().step_by(4) {
        println!("  omp={v:>2}: {t:>8.1} ex/s");
    }
    println!("KMP_BLOCKTIME marginal:");
    for (v, t) in fig6::marginal(&points, space::BLOCKTIME) {
        println!("  blocktime={v:>3}: {t:>8.1} ex/s");
    }

    let path = fig6::write_csv(&points, OUT_DIR.as_ref())?;
    println!(
        "\n{} points in {secs:.2}s here ({:.0} evals/s) vs ~{:.0} days on the paper's testbed",
        points.len(),
        points.len() as f64 / secs,
        findings.paper_equiv_days
    );
    println!("csv: {}", path.display());
    Ok(())
}
