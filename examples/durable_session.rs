//! The durable persistence plane, end to end in one binary: a tuning
//! campaign journals every observation into a state directory, "crashes"
//! halfway, and is restored bit-identically — snapshot + WAL-suffix
//! replay — before finishing its budget.
//!
//!     cargo run --release --example durable_session [iters]
//!
//! The same deployment with real processes:
//!
//!     tftune surrogate-serve --addr 127.0.0.1:7071 \
//!         --state-dir /var/lib/tftune/campaign &
//!     # kill -9 it at any point, then run the identical command again:
//!     # it recovers the factor from snapshot + WAL and keeps serving.
//!
//!     tftune tune --model ncf-fp32 --alg bo --iters 60 \
//!         --state-dir /var/lib/tftune/session --resume

use anyhow::Result;
use tftune::gp::{GpHyper, SharedSurrogate, SurrogateDelta};
use tftune::persist::{self, PersistOptions};
use tftune::sim::ModelId;
use tftune::space::SearchSpace;
use tftune::util::Rng;

/// Every observation row and the packed Cholesky factor as raw bit
/// patterns: equality here is the "bit-identical" durability claim,
/// not an epsilon comparison.
fn bits(delta: &SurrogateDelta) -> (Vec<u64>, Vec<u64>) {
    let mut rows = Vec::new();
    for (x, y) in &delta.rows {
        rows.extend(x.iter().map(|v| v.to_bits()));
        rows.push(y.to_bits());
    }
    let factor: Vec<u64> = match &delta.factor {
        Some(f) => f.iter().map(|v| v.to_bits()).collect(),
        None => Vec::new(),
    };
    (rows, factor)
}

fn tell_campaign(surrogate: &SharedSurrogate, space: &SearchSpace, seed: u64, n: usize) {
    // A stand-in for expensive real measurements: random configs scored
    // by the simulator-shaped toy objective.
    let mut rng = Rng::new(seed);
    let d = space.dim();
    for _ in 0..n {
        let x: Vec<f64> = (0..d).map(|_| rng.f64()).collect();
        let y = (3.0 * x[0]).sin() - 0.5 * x[d - 1];
        surrogate.tell(x, y);
    }
    drop(surrogate.lock()); // drain → factor append → WAL append
}

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let iters: usize = args.first().map(|s| s.parse()).transpose()?.unwrap_or(40);
    let iters = iters.max(4); // each phase needs at least one observation
    let space = ModelId::NcfFp32.space();

    let dir = std::env::temp_dir().join("tftune_example_durable");
    let _ = std::fs::remove_dir_all(&dir);

    // Phase 1: a fresh campaign. recover() on an empty directory is the
    // cold start — one code path for boot and reboot alike.
    let booted = persist::recover(&dir, GpHyper::default())?;
    let surrogate = booted.surrogate;
    let persistence = persist::attach(&surrogate, &dir, PersistOptions::default())?;
    tell_campaign(&surrogate, &space, 7, iters / 2);
    let seq = persistence.snapshot(&surrogate)?;
    println!("campaign: {} observations, snapshot @{seq}", surrogate.len());

    // More observations after the snapshot: these live only in the WAL.
    tell_campaign(&surrogate, &space, 8, iters / 4);
    println!(
        "campaign: {} observations ({} of them WAL-only) … and the process dies here",
        surrogate.len(),
        surrogate.len() - seq
    );
    drop(persistence); // simulate the crash: no final snapshot
    let pre_crash = surrogate.export_delta(0).expect("full export");
    drop(surrogate);

    // Phase 2: the restart. Newest valid snapshot seeds the store, the
    // WAL suffix replays through the ordinary drain path, and the packed
    // Cholesky factor comes back bit-for-bit.
    let recovered = persist::recover(&dir, GpHyper::default())?;
    println!(
        "recovery: snapshot {:?} + {} WAL record(s) replayed → {} observations",
        recovered.snapshot_seq,
        recovered.replayed,
        recovered.surrogate.len()
    );
    let restored = recovered.surrogate.export_delta(0).expect("full export");
    let (rows_a, factor_a) = bits(&pre_crash);
    let (rows_b, factor_b) = bits(&restored);
    assert_eq!(rows_a, rows_b, "recovered rows are not bit-identical");
    assert_eq!(factor_a, factor_b, "recovered factor is not bit-identical");
    assert!(!factor_b.is_empty(), "recovered factor missing");
    println!("recovery: rows and packed factor verified bit-identical");

    // Phase 3: finish the budget on the restored model — re-attach the
    // journal (never before recover(), so replay is not re-journaled)
    // and keep going as if nothing happened.
    let surrogate = recovered.surrogate;
    let persistence = persist::attach(&surrogate, &dir, PersistOptions::default())?;
    tell_campaign(&surrogate, &space, 9, iters - iters / 2 - iters / 4);
    persistence.snapshot(&surrogate)?;
    println!("resumed: {} observations, durable through the next crash", surrogate.len());

    let _ = std::fs::remove_dir_all(&dir);
    Ok(())
}
