//! End-to-end validation on a REAL workload (DESIGN.md §2): the system
//! under test is the AOT-compiled MLP executed via PJRT, and the objective
//! is *measured* examples/second — every layer of the stack composes:
//!
//!   L1 Pallas RBF kernel ─┐
//!   L2 JAX GP graph      ─┴─> gp.hlo.txt ──> PJRT ──> BO engine (L3)
//!   L2 JAX MLP workload  ───> workload_b*.hlo.txt ─> PJRT ─> evaluator
//!
//! The tuner picks the batch size; the evaluator times real executions.
//! Reports the tuning trace, the measured per-batch throughput table, the
//! achieved FLOP/s, and the result is recorded in EXPERIMENTS.md.
//!
//!     make artifacts && cargo run --release --example real_workload

use anyhow::Result;
use tftune::algorithms::BayesOpt;
use tftune::evaluator::{tune, Evaluator, RealWorkloadEvaluator};
use tftune::runtime::{GpSurrogate, Runtime, WorkloadRunner};

fn main() -> Result<()> {
    let rt = Runtime::open_default()?;
    let runner = WorkloadRunner::load(&rt)?;
    println!("loaded real workload: MLP {}→…→{} at batches {:?}", runner.d_in, runner.d_out, runner.batches);

    // Sanity: outputs are a probability simplex.
    let out = runner.run_once(runner.batches[0])?;
    let s: f32 = out[..runner.d_out].iter().sum();
    anyhow::ensure!((s - 1.0).abs() < 1e-3, "workload output not a simplex (sum {s})");

    // Ground truth: measure every batch variant directly.
    println!("\nmeasured throughput per compiled batch size (20 reps each):");
    let mut evaluator = RealWorkloadEvaluator::new(runner, 20);
    let space = evaluator.space();
    let mut truth = Vec::new();
    for idx in 0..space.params[0].n_values() as i64 {
        let t = evaluator.evaluate(&vec![idx])?;
        let batch = evaluator.batch_for(&vec![idx]);
        let gflops = t * evaluator.flops_per_example() / 1e9;
        println!("  batch {batch:>4}: {t:>12.0} examples/s  ({gflops:.2} GFLOP/s achieved)");
        truth.push((batch, t));
    }

    // Now tune it like a black box with BO on the HLO GP surrogate.
    println!("\ntuning batch size with BO (HLO GP surrogate, 8 evaluations):");
    let gp = GpSurrogate::load(&rt)?;
    let mut bo = BayesOpt::with_surrogate(space.clone(), 7, gp);
    let history = tune(&mut bo, &mut evaluator, 8)?;
    for e in history.iter() {
        println!(
            "  iter {:>2}: batch {:>4} -> {:>12.0} examples/s",
            e.iteration,
            evaluator.batch_for(&e.config),
            e.value
        );
    }
    let best = history.best().unwrap();
    let best_batch = evaluator.batch_for(&best.config);
    let (true_best_batch, true_best) = truth
        .iter()
        .cloned()
        .max_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
        .unwrap();
    println!(
        "\ntuner found batch {best_batch} at {:.0} ex/s; ground-truth best is batch {true_best_batch} at {true_best:.0} ex/s",
        best.value
    );
    anyhow::ensure!(
        best_batch == true_best_batch || best.value > 0.8 * true_best,
        "tuner missed the ground-truth optimum badly"
    );
    println!("end-to-end OK: tuner + PJRT runtime + AOT artifacts compose on a real workload");
    Ok(())
}
