//! Parallel tuning with the ask/tell session driver: one BO engine, four
//! simulator evaluators measuring concurrently, a composite budget
//! (evaluation cap + wall-clock limit + plateau stop), and a per-trial
//! callback streaming completions as they land — the building blocks for
//! sharding measurements across many targets.
//!
//!     cargo run --release --example parallel_tuning [parallel] [iters]
//!
//! Migration note (propose/observe -> ask/tell): where old code wrote
//! `let cfg = tuner.propose(); tuner.observe(&cfg, value)`, ask/tell code
//! writes `let t = tuner.ask(1).pop().unwrap(); tuner.tell(t.id, &m)` —
//! and a `TuningSession` does exactly that for you, n trials at a time.

use anyhow::Result;
use tftune::algorithms::Algorithm;
use tftune::evaluator::{sim_pool, Objective};
use tftune::session::{Budget, TuningSession};
use tftune::sim::ModelId;

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let parallel: usize = args.first().map(|s| s.parse()).transpose()?.unwrap_or(4);
    let iters: usize = args.get(1).map(|s| s.parse()).transpose()?.unwrap_or(40);

    let model = ModelId::Resnet50Fp32;
    let space = model.space();
    println!(
        "tuning {} with BO: {iters} evaluations over {parallel} parallel evaluator(s)",
        model.name()
    );

    let budget = Budget::evaluations(iters)
        .with_max_seconds(60.0)
        .with_plateau(25, 0.001);
    let tuner = Algorithm::Bo.build(&space, 0);
    let pool = sim_pool(
        model,
        0,
        tftune::sim::noise::DEFAULT_SIGMA,
        Objective::Throughput,
        parallel,
    );

    let t0 = std::time::Instant::now();
    let mut session = TuningSession::new(tuner, pool, budget).on_trial(|trial, m| {
        println!(
            "  trial {:>3} done: {:>8.1} examples/s  (measured in {:.3}s)",
            trial.id, m.value, m.cost_s
        );
    });
    let history = session.run()?;
    let wall = t0.elapsed().as_secs_f64();

    let best = history.best().expect("non-empty history");
    println!(
        "\nstopped by {} after {} trials in {wall:.2}s wall clock \
         ({:.2}s of measurement time packed onto {parallel} evaluator(s))",
        session.stop_reason().map(|r| r.name()).unwrap_or("?"),
        history.len(),
        history.total_cost_s(),
    );
    println!("best: {:.1} examples/s at trial {}", best.value, best.trial_id);
    println!("best config: {}", space.config_to_json(&best.config));
    Ok(())
}
