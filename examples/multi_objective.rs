//! Multi-objective tuning: throughput vs p99 latency on one GP factor.
//!
//! The knobs this system tunes (inter/intra-op threads, batch,
//! `OMP_NUM_THREADS`) trade throughput against tail latency, so instead
//! of collapsing to a single scalar the run declares an `ObjectiveSet` —
//! the primary `value` plus the `p99_latency_ms` metadata column every
//! `SimEvaluator::measure` already attaches — and the BO engine scores
//! *both* objectives per candidate in one blocked panel pass over one
//! Cholesky factor (K target columns, not K refits), proposing by
//! SMSego-style hypervolume gain over the non-dominated front.
//!
//!     cargo run --release --example multi_objective [iters]
//!
//! The history records each trial's objective vector, so the Pareto
//! front prints straight off the returned `History`.

use anyhow::Result;
use tftune::algorithms::BayesOpt;
use tftune::evaluator::sim_pool;
use tftune::session::{Budget, TuningSession};
use tftune::sim::ModelId;
use tftune::{ObjectiveSet, Scalarization};

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let iters: usize = args.first().map(|s| s.parse()).transpose()?.unwrap_or(40);

    let model = ModelId::BertFp32;
    let space = model.space();
    let set = ObjectiveSet::parse("throughput,p99_latency_ms:min")
        .map_err(|e| anyhow::anyhow!(e))?;
    println!(
        "tuning {} over [{}] for {iters} evaluations (SMSego hypervolume gain)",
        model.name(),
        set.spec()
    );

    let tuner = Box::new(
        BayesOpt::new(space.clone(), 7).with_objectives(set.clone(), Scalarization::Smsego),
    );
    let mut session = TuningSession::new(
        tuner,
        sim_pool(
            model,
            7,
            tftune::sim::noise::DEFAULT_SIGMA,
            tftune::evaluator::Objective::Throughput,
            2,
        ),
        Budget::evaluations(iters),
    )
    .with_objectives(set.clone());

    let history = session.run()?;

    // The recorded objective vectors are maximisation-oriented (p99 is
    // negated), so flip the sign back for display.
    let mut front = history.pareto_front();
    front.sort_by(|a, b| a.objectives[0].total_cmp(&b.objectives[0]));
    println!(
        "\nnon-dominated front: {} of {} trials (throughput up, p99 down):",
        front.len(),
        history.len()
    );
    println!("{:>12}  {:>10}  config", "examples/s", "p99 (ms)");
    for e in &front {
        println!(
            "{:>12.1}  {:>10.3}  {}",
            e.objectives[0],
            -e.objectives[1],
            space.config_to_json(&e.config)
        );
    }
    Ok(())
}
