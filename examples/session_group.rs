//! Multiple concurrent tuning sessions sharing ONE surrogate: a
//! `SessionGroup` of BO sessions over the same search space, every
//! engine borrowing a handle to a single `SharedSurrogate`, so each
//! session's measurements sharpen every other session's proposals — the
//! amortised-surrogate regime the paper's practicality argument rests on.
//!
//!     cargo run --release --example session_group [sessions] [iters]
//!
//! Compare the printed per-session bests with a lone 40-evaluation run:
//! later sessions start from a factor already conditioned on the whole
//! group's history.

use anyhow::Result;
use tftune::evaluator::{sim_pool, Objective};
use tftune::session::{Budget, SessionGroup};
use tftune::sim::ModelId;

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let sessions: usize = args.first().map(|s| s.parse()).transpose()?.unwrap_or(3);
    let iters: usize = args.get(1).map(|s| s.parse()).transpose()?.unwrap_or(24);

    let model = ModelId::Resnet50Fp32;
    let space = model.space();
    println!(
        "{sessions} concurrent BO sessions x {iters} evaluations on {}, one shared surrogate",
        model.name()
    );

    let seeds: Vec<u64> = (0..sessions as u64).collect();
    let (shared, mut group) =
        SessionGroup::shared_bo(&space, &seeds, Budget::evaluations(iters), |i| {
            sim_pool(
                model,
                1000 + i as u64,
                tftune::sim::noise::DEFAULT_SIGMA,
                Objective::Throughput,
                2, // two evaluator threads per session
            )
        });

    let t0 = std::time::Instant::now();
    let histories = group.run()?;
    let wall = t0.elapsed().as_secs_f64();

    for (i, h) in histories.iter().enumerate() {
        let best = h.best().expect("non-empty history");
        println!(
            "session {i}: best {:>8.1} examples/s over {} trials",
            best.value,
            h.len()
        );
    }
    println!(
        "\n{} observations conditioned one shared factor in {wall:.2}s wall clock",
        shared.total_observations()
    );
    Ok(())
}
