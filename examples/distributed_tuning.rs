//! The paper's Fig. 4 deployment, scaled out: the optimization framework
//! (host) and the system under test (target) are separate processes
//! talking over TCP, so the tuner's compute cannot perturb the
//! measurements — and with the ask/tell session the host shards its
//! in-flight trials across *several* target daemons at once.
//!
//! This example runs two target daemons on background threads, then tunes
//! BERT-FP32 over the wire with all three paper algorithms, two trials in
//! flight at any moment (one per daemon connection).
//!
//!     cargo run --release --example distributed_tuning

use anyhow::Result;
use tftune::algorithms::Algorithm;
use tftune::evaluator::{Evaluator, RemoteEvaluator, SimEvaluator};
use tftune::server::TargetServer;
use tftune::session::{Budget, TuningSession};
use tftune::sim::ModelId;

fn main() -> Result<()> {
    let model = ModelId::BertFp32;
    let space = model.space();

    // Target side: two daemons, e.g. two machines in the paper's testbed.
    let mut addrs = Vec::new();
    let mut handles = Vec::new();
    for seed in [42, 43] {
        let server = TargetServer::bind(
            "127.0.0.1:0",
            space.clone(),
            Box::new(SimEvaluator::new(model, seed)),
        )?;
        let (addr, handle) = server.spawn()?;
        println!("target daemon listening on {addr} ({})", model.name());
        addrs.push(addr.to_string());
        handles.push(handle);
    }
    let addr_list = addrs.join(",");

    // Host side: one session per algorithm, one connection per daemon.
    for alg in Algorithm::all_paper() {
        let remotes = RemoteEvaluator::connect_all(&addr_list, &space)?;
        println!("\nhost connected to {} daemons for {}", remotes.len(), alg.name());
        let pool: Vec<Box<dyn Evaluator + Send>> = remotes
            .into_iter()
            .map(|r| Box::new(r) as Box<dyn Evaluator + Send>)
            .collect();
        let tuner = alg.build(&space, 7);
        let t0 = std::time::Instant::now();
        let mut session = TuningSession::new(tuner, pool, Budget::evaluations(24));
        let history = session.run()?;
        let best = history.best().unwrap();
        println!(
            "{:<24} best {:>7.1} examples/s at trial {:>2}  ({} evals over TCP in {:.2}s)",
            alg.name(),
            best.value,
            best.trial_id,
            history.len(),
            t0.elapsed().as_secs_f64()
        );
        println!("  best config: {}", space.config_to_json(&best.config));
    }

    // Shut the daemons down cleanly and report their evaluation counts.
    let mut served = 0;
    for addr in &addrs {
        let remote = RemoteEvaluator::connect(addr, space.clone())?;
        remote.shutdown()?;
    }
    for handle in handles {
        served += handle.join().expect("server thread")?;
    }
    println!("\ntarget daemons served {served} evaluations total");
    Ok(())
}
