//! The paper's Fig. 4 deployment: the optimization framework (host) and
//! the system under test (target) are separate processes talking over
//! TCP, so the tuner's compute cannot perturb the measurements.
//!
//! This example runs the target daemon on a background thread, then tunes
//! BERT-FP32 over the wire with all three paper algorithms.
//!
//!     cargo run --release --example distributed_tuning

use anyhow::Result;
use tftune::algorithms::Algorithm;
use tftune::evaluator::{tune, Evaluator, RemoteEvaluator, SimEvaluator};
use tftune::server::TargetServer;
use tftune::sim::ModelId;

fn main() -> Result<()> {
    let model = ModelId::BertFp32;
    let space = model.space();

    // Target side: the daemon that applies configs and measures.
    let server = TargetServer::bind(
        "127.0.0.1:0",
        space.clone(),
        Box::new(SimEvaluator::new(model, 42)),
    )?;
    let (addr, handle) = server.spawn()?;
    println!("target daemon listening on {addr} ({})", model.name());

    // Host side: one connection per algorithm engine.
    let mut last = None;
    for alg in Algorithm::all_paper() {
        let mut remote = RemoteEvaluator::connect(&addr.to_string(), space.clone())?;
        println!("\nhost connected to {}", remote.describe());
        let mut tuner = alg.build(&space, 7);
        let t0 = std::time::Instant::now();
        let history = tune(tuner.as_mut(), &mut remote, 25)?;
        let best = history.best().unwrap();
        println!(
            "{:<24} best {:>7.1} examples/s at iter {:>2}  ({} evals over TCP in {:.2}s)",
            alg.name(),
            best.value,
            best.iteration,
            history.len(),
            t0.elapsed().as_secs_f64()
        );
        println!("  best config: {}", space.config_to_json(&best.config));
        last = Some(remote);
    }

    // Shut the daemon down cleanly and report its evaluation count.
    last.unwrap().shutdown()?;
    let served = handle.join().expect("server thread")?;
    println!("\ntarget daemon served {served} evaluations total");
    Ok(())
}
