//! The paper's tuning loop, closed on ourselves: the repo's own BO
//! engine tunes the scoring engine's cache-blocking knobs
//! ([`tftune::gp::BlockSpec`] — mc/nc/kc) against *measured* timings of
//! the n=512 / 512-candidate panel pass. The objective is scoring
//! throughput (panel passes per second), so "best" means the block
//! shape that makes `score_into` fastest on *this* machine — the same
//! ask/tell conversation the paper runs against TensorFlow, with the
//! simulator swapped out for a real measurement.
//!
//!     cargo run --release --example self_tune_scoring [iters] [reps]
//!
//! The shipped `BlockSpec::default()` was picked with this example; rerun
//! it on new hardware before trusting the default there.

use anyhow::Result;
use tftune::algorithms::{BayesOpt, Tuner};
use tftune::gp::{BlockSpec, GpHyper, IncrementalGp, ScoreWorkspace};
use tftune::history::Measurement;
use tftune::space::{ParamDef, SearchSpace};
use tftune::util::Rng;

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let iters: usize = args.first().map(|s| s.parse()).transpose()?.unwrap_or(24);
    let reps: usize = args.get(1).map(|s| s.parse()).transpose()?.unwrap_or(5);

    // The system under test: a 512-point factor and a 512-candidate pool,
    // the scoring-engine bench shape (BENCH_gp.json `score_512_*`).
    let (n, d, c) = (512usize, 5usize, 512usize);
    let mut rng = Rng::new(0xB10C);
    let mut gp = IncrementalGp::new(GpHyper::default());
    for _ in 0..n {
        let x: Vec<f64> = (0..d).map(|_| rng.f64()).collect();
        let y = x[0] - 0.7 * x[1];
        assert!(gp.push(&x, y), "seed factor must stay positive definite");
    }
    let cand: Vec<f64> = (0..c * d).map(|_| rng.f64()).collect();
    let mut ws = ScoreWorkspace::default();

    // The search space: every blocking knob the kernels expose. Steps
    // keep the grid small enough that 24 evaluations see real coverage.
    let space = SearchSpace::new(vec![
        ParamDef::new("mc", 4, 64, 4),
        ParamDef::new("nc", 8, 128, 8),
        ParamDef::new("kc", 16, 256, 16),
    ]);

    // One measurement: the median of `reps` timed panel passes under the
    // candidate BlockSpec, reported as passes/second (maximised).
    let mut measure = |spec: BlockSpec| -> f64 {
        gp.set_block_spec(spec);
        let mut times: Vec<f64> = (0..reps)
            .map(|_| {
                let t0 = std::time::Instant::now();
                gp.score_into(&cand, c, 1.5, 0.0, &mut ws);
                t0.elapsed().as_secs_f64()
            })
            .collect();
        times.sort_by(|a, b| a.partial_cmp(b).unwrap());
        1.0 / times[times.len() / 2]
    };

    println!(
        "self-tuning BlockSpec over {} grid points ({iters} evaluations, \
         median of {reps} timed passes each)",
        space.size()
    );
    let baseline = measure(BlockSpec::default());
    let naive = measure(BlockSpec::naive());
    println!(
        "  shipped default {:?}: {baseline:.1} passes/s;  naive (unblocked): {naive:.1} passes/s",
        BlockSpec::default()
    );

    let mut bo = BayesOpt::new(space.clone(), 0);
    let mut best = (f64::NEG_INFINITY, BlockSpec::default());
    for i in 0..iters {
        let trial = bo.ask(1).pop().expect("engine always proposes");
        let spec = BlockSpec {
            mc: trial.config[0] as usize,
            nc: trial.config[1] as usize,
            kc: trial.config[2] as usize,
        };
        let passes = measure(spec);
        bo.tell(trial.id, &Measurement::new(passes));
        if passes > best.0 {
            best = (passes, spec);
            println!("  iter {i:>3}: {spec:?}  {passes:.1} passes/s  <- new best");
        }
    }

    println!(
        "\nbest BlockSpec on this machine: {:?} at {:.1} passes/s \
         ({:+.1}% vs shipped default, {:.2}x vs naive)",
        best.1,
        best.0,
        100.0 * (best.0 / baseline - 1.0),
        best.0 / naive
    );
    if best.0 > baseline * 1.05 {
        println!("consider updating BlockSpec::default() for this target");
    }
    Ok(())
}
