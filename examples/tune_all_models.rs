//! Fig. 5 driver: tune all six models with BO, GA and NMS (50 iterations,
//! 3 seeds) and print the per-model winner table — the paper's headline
//! comparison.
//!
//!     cargo run --release --example tune_all_models [iters] [seeds]

use anyhow::Result;
use tftune::config::SurrogateKind;
use tftune::figures::{fig5, OUT_DIR};

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let iters: usize = args.first().map(|s| s.parse()).transpose()?.unwrap_or(50);
    let n_seeds: u64 = args.get(1).map(|s| s.parse()).transpose()?.unwrap_or(3);
    let seeds: Vec<u64> = (0..n_seeds).collect();

    println!("running Fig. 5: 6 models x {{BO, GA, NMS}} x {n_seeds} seeds x {iters} iterations");
    let t0 = std::time::Instant::now();
    let curves = fig5::run_figure(iters, &seeds, SurrogateKind::Native, OUT_DIR.as_ref())?;
    fig5::print_summary(&curves);
    println!(
        "\n{} tuning runs ({} evaluations) in {:.2}s; CSV series under {OUT_DIR}/",
        curves.len(),
        curves.len() * iters,
        t0.elapsed().as_secs_f64()
    );
    Ok(())
}
