//! Quickstart: tune ResNet50-INT8's five threading parameters with
//! Bayesian optimization in 30 evaluations and compare against the
//! TensorFlow-style default configuration.
//!
//!     cargo run --release --example quickstart
//!
//! Uses the AOT HLO GP artifact when `artifacts/` exists (the production
//! path: L1 Pallas kernel + L2 JAX graph via PJRT), the native GP
//! otherwise.

use anyhow::Result;
use tftune::algorithms::Algorithm;
use tftune::config::{SurrogateKind, TuneConfig};
use tftune::sim::{ModelId, SimWorkload};

fn main() -> Result<()> {
    let model = ModelId::Resnet50Int8;
    let space = model.space();

    // The baseline a non-savvy user gets: TF defaults (inter=2,
    // intra=#cores) with the OpenMP guide's blocktime recommendation.
    let default_cfg = vec![2, 48, 64, 200, 48];
    let baseline = SimWorkload::noiseless(model).true_throughput(&default_cfg);
    println!("model: {}", model.name());
    println!("default config {:?} -> {baseline:.1} examples/s", default_cfg);

    let surrogate = if tftune::runtime::find_artifacts_dir().is_some() {
        println!("using the AOT HLO GP surrogate (PJRT)");
        SurrogateKind::Hlo
    } else {
        println!("artifacts/ not found; using the native GP surrogate");
        SurrogateKind::Native
    };

    let cfg = TuneConfig {
        model,
        algorithm: Algorithm::Bo,
        iterations: 30,
        seed: 0,
        surrogate,
        ..Default::default()
    };
    let history = cfg.run()?;

    println!("\niter  measured(ex/s)  best-so-far");
    let best_curve = history.best_curve();
    for (e, b) in history.iter().zip(&best_curve) {
        println!("{:>4}  {:>14.1}  {:>11.1}", e.iteration, e.value, b);
    }

    let best = history.best().unwrap();
    println!("\nbest config: {}", space.config_to_json(&best.config));
    println!(
        "tuned {:.1} vs default {baseline:.1} examples/s  ({:.2}x speedup in {} evaluations)",
        best.value,
        best.value / baseline,
        history.len()
    );
    Ok(())
}
