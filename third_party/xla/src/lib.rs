//! Stub of the `xla` (xla_extension) PJRT bindings used by `tftune`.
//!
//! The build image this repo targets no longer vendors the real
//! xla_extension closure, so this stub provides the same API surface with
//! runtime failure at the PJRT boundary: `PjRtClient::cpu()` returns an
//! error, which the tftune runtime layer already treats as "artifacts
//! unavailable" (BO falls back to the exact native GP surrogate and the
//! artifact integration tests skip). [`Literal`] is implemented for real —
//! it is pure host-side data marshalling and unit tests exercise it.

use std::fmt;

/// Error type for every stubbed PJRT operation.
#[derive(Debug, Clone)]
pub struct Error {
    message: String,
}

impl Error {
    fn unavailable(what: &str) -> Error {
        Error {
            message: format!(
                "{what}: PJRT runtime unavailable (built against the in-tree xla stub; \
                 vendor the real xla_extension crate to enable HLO artifacts)"
            ),
        }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.message)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

/// Host-side f32 tensor literal (the only element type tftune marshals).
#[derive(Debug, Clone, PartialEq)]
pub struct Literal {
    data: Vec<f32>,
    dims: Vec<i64>,
}

impl Literal {
    /// Rank-1 literal from a slice.
    pub fn vec1(data: &[f32]) -> Literal {
        Literal { data: data.to_vec(), dims: vec![data.len() as i64] }
    }

    /// Reinterpret with new dimensions (element count must match).
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let n: i64 = dims.iter().product();
        if n as usize != self.data.len() {
            return Err(Error {
                message: format!(
                    "reshape: {} elements do not fit dims {dims:?}",
                    self.data.len()
                ),
            });
        }
        Ok(Literal { data: self.data.clone(), dims: dims.to_vec() })
    }

    /// Copy the elements out.
    pub fn to_vec<T: From<f32>>(&self) -> Result<Vec<T>> {
        Ok(self.data.iter().map(|&v| T::from(v)).collect())
    }

    pub fn dims(&self) -> &[i64] {
        &self.dims
    }

    /// Unpack a 1-tuple result.
    pub fn to_tuple1(self) -> Result<Literal> {
        Err(Error::unavailable("Literal::to_tuple1"))
    }

    /// Unpack a 3-tuple result.
    pub fn to_tuple3(self) -> Result<(Literal, Literal, Literal)> {
        Err(Error::unavailable("Literal::to_tuple3"))
    }
}

/// Parsed HLO module (stub: never constructible at runtime).
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(Error::unavailable("HloModuleProto::from_text_file"))
    }
}

/// XLA computation wrapper.
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// Device buffer handle returned by an execution.
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::unavailable("PjRtBuffer::to_literal_sync"))
    }
}

/// Compiled executable handle.
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::unavailable("PjRtLoadedExecutable::execute"))
    }
}

/// PJRT client handle. `cpu()` is the single runtime entry point and it
/// fails, so no stubbed executable can ever be reached in practice.
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(Error::unavailable("PjRtClient::cpu"))
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::unavailable("PjRtClient::compile"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_round_trip_and_reshape() {
        let l = Literal::vec1(&[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(l.dims(), &[4]);
        let r = l.reshape(&[2, 2]).unwrap();
        assert_eq!(r.dims(), &[2, 2]);
        assert_eq!(r.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(l.reshape(&[3, 2]).is_err());
    }

    #[test]
    fn runtime_entry_points_fail_loudly() {
        let e = PjRtClient::cpu().err().unwrap();
        assert!(e.to_string().contains("PJRT runtime unavailable"));
        assert!(HloModuleProto::from_text_file("x.hlo.txt").is_err());
    }
}
