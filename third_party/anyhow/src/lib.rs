//! Minimal, API-compatible subset of the `anyhow` crate.
//!
//! The offline build image vendors no crates.io registry, so the pieces of
//! anyhow the tftune crate actually uses are reimplemented here behind the
//! same names: [`Error`], [`Result`], the [`Context`] extension trait and
//! the `anyhow!` / `bail!` / `ensure!` macros. Error values carry a message
//! plus an optional boxed source, and `Display` shows the outermost
//! context message exactly like the real crate.

use std::fmt;

/// Error type: an owned message with an optional source chain.
pub struct Error {
    msg: String,
    source: Option<Box<dyn std::error::Error + Send + Sync + 'static>>,
}

/// `anyhow::Result<T>` defaults the error type to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

impl Error {
    /// Build an error from any displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { msg: message.to_string(), source: None }
    }

    /// Wrap a standard error as the source of a new `Error`.
    pub fn new<E>(error: E) -> Error
    where
        E: std::error::Error + Send + Sync + 'static,
    {
        Error { msg: error.to_string(), source: Some(Box::new(error)) }
    }

    /// Add a context message in front of this error.
    pub fn context<C: fmt::Display>(self, context: C) -> Error {
        Error { msg: format!("{context}: {}", self.msg), source: self.source }
    }

    /// The outermost message (the full rendered chain).
    pub fn to_string_chain(&self) -> String {
        self.msg.clone()
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)?;
        if let Some(src) = &self.source {
            write!(f, "\n\nCaused by:\n    {src}")?;
        }
        Ok(())
    }
}

// NOTE: `Error` deliberately does NOT implement `std::error::Error`; that is
// what makes this blanket conversion coherent (same shape as real anyhow).
impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(error: E) -> Error {
        Error::new(error)
    }
}

/// Extension trait adding `.context(..)` / `.with_context(..)` to `Result`
/// and `Option`, mirroring anyhow's.
pub trait Context<T, E>: Sized {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error>;
}

impl<T, E> Context<T, E> for Result<T, E>
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| Error { msg: format!("{context}: {e}"), source: Some(Box::new(e)) })
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        match self {
            Ok(v) => Ok(v),
            Err(e) => Err(Error { msg: format!("{}: {e}", f()), source: Some(Box::new(e)) }),
        }
    }
}

impl<T> Context<T, Error> for Result<T, Error> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| e.context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| e.context(f()))
    }
}

impl<T> Context<T, std::convert::Infallible> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Build an [`Error`] from a message, a format string, or another error.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Return early with an error.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error when a condition does not hold.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!(concat!("condition failed: ", stringify!($cond)))
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*)
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::Other, "boom")
    }

    #[test]
    fn context_on_result_and_option() {
        let r: Result<(), std::io::Error> = Err(io_err());
        let e = r.context("reading file").unwrap_err();
        assert_eq!(e.to_string(), "reading file: boom");

        let o: Option<u32> = None;
        let e = o.with_context(|| format!("missing {}", 7)).unwrap_err();
        assert_eq!(e.to_string(), "missing 7");
    }

    #[test]
    fn macros_build_messages() {
        fn f(fail: bool) -> Result<u32> {
            ensure!(!fail, "value was {}", 42);
            Ok(1)
        }
        assert!(f(false).is_ok());
        assert_eq!(f(true).unwrap_err().to_string(), "value was 42");
        let e: Error = anyhow!("x {}", 3);
        assert_eq!(e.to_string(), "x 3");
        let e: Error = anyhow!(String::from("owned"));
        assert_eq!(e.to_string(), "owned");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn f() -> Result<String> {
            let s = std::fs::read_to_string("/definitely/not/a/path")?;
            Ok(s)
        }
        assert!(f().is_err());
    }

    #[test]
    fn chained_context_keeps_outer_message_first() {
        let r: Result<(), std::io::Error> = Err(io_err());
        let e = r.context("inner").context("outer").unwrap_err();
        assert_eq!(e.to_string(), "outer: inner: boom");
        assert!(format!("{e:?}").contains("Caused by"));
    }
}
