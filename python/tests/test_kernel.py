"""L1 correctness: the Pallas RBF kernel vs. the pure-jnp oracle.

This is the CORE correctness signal for the kernel that ends up inside the
AOT GP artifact. hypothesis sweeps shapes, tile sizes and hyperparameters;
directed tests cover the edges (tile-boundary shapes, degenerate inputs,
dtype promotion).
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

from compile.kernels.ref import rbf_kernel_matrix_ref
from compile.kernels.rbf import (
    TILE_M,
    TILE_N,
    mxu_flops_per_block,
    rbf_kernel_matrix,
    vmem_footprint_bytes,
)

RTOL = 2e-5
ATOL = 2e-6


def _points(rng, n, d, scale=1.0):
    return (rng.normal(size=(n, d)) * scale).astype(np.float32)


# ---------------------------------------------------------------------------
# hypothesis sweeps
# ---------------------------------------------------------------------------


@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(1, 90),
    m=st.integers(1, 90),
    d=st.integers(1, 12),
    seed=st.integers(0, 2**31 - 1),
)
def test_rbf_matches_ref_shapes(n, m, d, seed):
    rng = np.random.default_rng(seed)
    a, b = _points(rng, n, d), _points(rng, m, d)
    got = np.asarray(rbf_kernel_matrix(a, b, 0.5, 1.0))
    want = np.asarray(rbf_kernel_matrix_ref(a, b, 0.5, 1.0))
    assert got.shape == (n, m)
    assert_allclose(got, want, rtol=RTOL, atol=ATOL)


@settings(max_examples=20, deadline=None)
@given(
    ls=st.floats(0.05, 10.0),
    var=st.floats(0.01, 50.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_rbf_hyperparameters(ls, var, seed):
    rng = np.random.default_rng(seed)
    a, b = _points(rng, 17, 5), _points(rng, 23, 5)
    got = np.asarray(rbf_kernel_matrix(a, b, ls, var))
    want = np.asarray(rbf_kernel_matrix_ref(a, b, ls, var))
    assert_allclose(got, want, rtol=1e-4, atol=1e-5 * var)


@settings(max_examples=10, deadline=None)
@given(
    tile_n=st.sampled_from([8, 16, 32, 64]),
    tile_m=st.sampled_from([8, 16, 32, 64]),
    seed=st.integers(0, 2**31 - 1),
)
def test_rbf_tile_size_invariance(tile_n, tile_m, seed):
    """The tiling is an implementation detail: results must not depend on it."""
    rng = np.random.default_rng(seed)
    a, b = _points(rng, 50, 6), _points(rng, 41, 6)
    got = np.asarray(rbf_kernel_matrix(a, b, 0.8, 2.0, tile_n=tile_n, tile_m=tile_m))
    want = np.asarray(rbf_kernel_matrix_ref(a, b, 0.8, 2.0))
    assert_allclose(got, want, rtol=RTOL, atol=ATOL)


# ---------------------------------------------------------------------------
# directed edge cases
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n,m", [(1, 1), (64, 64), (65, 63), (128, 1), (1, 128)])
def test_rbf_tile_boundaries(n, m):
    rng = np.random.default_rng(7)
    a, b = _points(rng, n, 5), _points(rng, m, 5)
    got = np.asarray(rbf_kernel_matrix(a, b, 0.3, 1.0))
    want = np.asarray(rbf_kernel_matrix_ref(a, b, 0.3, 1.0))
    assert_allclose(got, want, rtol=RTOL, atol=ATOL)


def test_rbf_diagonal_is_variance():
    """K(x, x) ~= variance (f32 cancellation in the matmul form is clamped
    at 0 but can leave a tiny positive residual distance)."""
    rng = np.random.default_rng(1)
    a = _points(rng, 33, 5, scale=10.0)
    k = np.asarray(rbf_kernel_matrix(a, a, 0.7, 2.5))
    assert_allclose(np.diag(k), np.full(33, 2.5), rtol=1e-3)
    assert (np.diag(k) <= 2.5 + 1e-6).all()


def test_rbf_symmetry():
    rng = np.random.default_rng(2)
    a = _points(rng, 40, 5)
    k = np.asarray(rbf_kernel_matrix(a, a, 0.4, 1.0))
    assert_allclose(k, k.T, rtol=0, atol=1e-6)


def test_rbf_values_in_range():
    """0 <= K <= variance for any inputs (exp underflows to +0 in f32 at
    large distances, never negative)."""
    rng = np.random.default_rng(3)
    a, b = _points(rng, 30, 5, scale=5.0), _points(rng, 31, 5, scale=5.0)
    k = np.asarray(rbf_kernel_matrix(a, b, 0.2, 3.0))
    assert (k >= 0).all() and (k <= 3.0 + 1e-6).all()


def test_rbf_identical_points_far_points():
    a = np.zeros((4, 5), np.float32)
    b = np.full((4, 5), 100.0, np.float32)
    k_same = np.asarray(rbf_kernel_matrix(a, a, 1.0, 1.0))
    k_far = np.asarray(rbf_kernel_matrix(a, b, 1.0, 1.0))
    assert_allclose(k_same, np.ones((4, 4)), rtol=1e-6)
    assert (k_far < 1e-30).all()

def test_rbf_rejects_bad_shapes():
    with pytest.raises(ValueError):
        rbf_kernel_matrix(np.zeros((3, 4), np.float32), np.zeros((3, 5), np.float32), 1.0, 1.0)
    with pytest.raises(ValueError):
        rbf_kernel_matrix(np.zeros((3,), np.float32), np.zeros((3, 5), np.float32), 1.0, 1.0)


def test_rbf_accepts_f64_input():
    """Inputs get cast to f32; result must still match the f32 oracle."""
    rng = np.random.default_rng(4)
    a64 = rng.normal(size=(9, 5))
    b64 = rng.normal(size=(11, 5))
    got = np.asarray(rbf_kernel_matrix(a64, b64, 0.5, 1.0))
    want = np.asarray(
        rbf_kernel_matrix_ref(a64.astype(np.float32), b64.astype(np.float32), 0.5, 1.0)
    )
    assert_allclose(got, want, rtol=RTOL, atol=ATOL)


# ---------------------------------------------------------------------------
# perf-model metadata (DESIGN.md §Hardware-Adaptation numbers stay honest)
# ---------------------------------------------------------------------------


def test_vmem_footprint_under_budget():
    # default tiles, d=8: must sit far below a 16 MiB VMEM budget.
    assert vmem_footprint_bytes(TILE_N, TILE_M, 8) < 1 << 20


def test_mxu_flops_accounting():
    assert mxu_flops_per_block(64, 64, 8) == 2 * 64 * 64 * 8
