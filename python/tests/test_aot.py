"""AOT pipeline checks: HLO text artifacts are well-formed and consistent."""

import json
import os

import pytest

from compile import aot, model

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def test_gp_hlo_text_well_formed():
    text = aot.lower_gp()
    assert "ENTRY" in text and "HloModule" in text
    # fixed-shape contract visible in the HLO signature
    assert f"f32[{model.N_PAD},{model.D_FEAT}]" in text
    assert f"f32[{model.C_CAND},{model.D_FEAT}]" in text
    # the CG loop must have lowered to a While op, not a LAPACK custom-call
    assert "while" in text
    assert "lapack" not in text.lower()
    assert "custom-call" not in text.lower()


def test_workload_hlo_text_well_formed():
    text = aot.lower_workload(8)
    assert "ENTRY" in text
    assert f"f32[8,{model.WORKLOAD_IN}]" in text
    assert "custom-call" not in text.lower()


def test_lowering_is_deterministic():
    assert aot.lower_workload(1) == aot.lower_workload(1)


def test_meta_matches_model_constants():
    meta = aot.build_meta()
    assert meta["gp"]["n_pad"] == model.N_PAD
    assert meta["gp"]["d_feat"] == model.D_FEAT
    assert meta["gp"]["c_cand"] == model.C_CAND
    assert meta["gp"]["hyper"][4] == "y_best"
    assert meta["workload"]["batches"] == list(model.WORKLOAD_BATCHES)
    assert meta["workload"]["flops_per_example"] == model.workload_flops_per_example()
    json.dumps(meta)  # must be JSON-serialisable


@pytest.mark.skipif(
    not os.path.exists(os.path.join(ART, "meta.json")),
    reason="artifacts not built yet (run `make artifacts`)",
)
def test_built_artifacts_consistent():
    """If artifacts/ exists it must match the current shape contract."""
    with open(os.path.join(ART, "meta.json")) as f:
        meta = json.load(f)
    assert meta == aot.build_meta()
    for fname in ["gp.hlo.txt"] + [
        f"workload_b{b}.hlo.txt" for b in meta["workload"]["batches"]
    ]:
        path = os.path.join(ART, fname)
        assert os.path.exists(path), fname
        with open(path) as f:
            head = f.read(4096)
        assert "HloModule" in head
