"""L2 correctness: the CG-based GP graph vs. the dense-solve oracle."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

from compile import model
from compile.kernels.ref import gp_posterior_ref, smsego_gain_ref


def _padded_problem(rng, n_real, ls=0.25, sv=1.0, nv=1e-3, alpha=1.5):
    # defaults sit inside the artifact's supported envelope (ls <= 0.25)
    # and at the graph's conditioning floor (nv >= 1e-3) so the dense
    # oracle and the CG graph solve the same system.
    N, D, C = model.N_PAD, model.D_FEAT, model.C_CAND
    xtr = np.zeros((N, D), np.float32)
    xtr[:n_real, :5] = rng.uniform(size=(n_real, 5))
    ytr = np.zeros((N,), np.float32)
    ytr[:n_real] = rng.normal(size=n_real)
    mask = np.zeros((N,), np.float32)
    mask[:n_real] = 1.0
    xcand = np.zeros((C, D), np.float32)
    xcand[:, :5] = rng.uniform(size=(C, 5))
    y_best = float(ytr[:n_real].max())
    hyper = np.array([ls, sv, nv, alpha, y_best], np.float32)
    return xtr, ytr, mask, xcand, hyper


@settings(max_examples=8, deadline=None)
@given(n_real=st.integers(2, 64), seed=st.integers(0, 2**31 - 1))
def test_gp_matches_dense_oracle(n_real, seed):
    rng = np.random.default_rng(seed)
    xtr, ytr, mask, xcand, hyper = _padded_problem(rng, n_real)
    mu, sigma, gain = (np.asarray(v) for v in model.gp_fit_predict(xtr, ytr, mask, xcand, hyper))
    mu_ref, var_ref = gp_posterior_ref(
        xtr[:n_real], ytr[:n_real], xcand, hyper[0], hyper[1], hyper[2]
    )
    assert_allclose(mu, np.asarray(mu_ref), rtol=1e-3, atol=1e-3)
    assert_allclose(sigma, np.sqrt(np.asarray(var_ref)), rtol=1e-2, atol=1e-3)
    want_gain = smsego_gain_ref(mu, sigma, hyper[4], hyper[3])
    assert_allclose(gain, np.asarray(want_gain), rtol=1e-5, atol=1e-6)


@settings(max_examples=6, deadline=None)
@given(ls=st.floats(0.05, 0.25), seed=st.integers(0, 2**31 - 1))
def test_gp_converges_across_supported_lengthscales(ls, seed):
    """Envelope regression (EXPERIMENTS.md §Perf): CG_ITERS must keep the
    solve converged for every lengthscale the artifact supports (<= 0.25),
    at the hardest case n = N_PAD."""
    rng = np.random.default_rng(seed)
    xtr, ytr, mask, xcand, hyper = _padded_problem(rng, model.N_PAD, ls=ls)
    mu, _, _ = (np.asarray(v) for v in model.gp_fit_predict(xtr, ytr, mask, xcand, hyper))
    mu_ref, _ = gp_posterior_ref(xtr, ytr, xcand, ls, hyper[1], hyper[2])
    assert_allclose(mu, np.asarray(mu_ref), rtol=2e-3, atol=2e-3)


def test_padding_is_inert():
    """Adding garbage rows under mask=0 must not change the posterior."""
    rng = np.random.default_rng(11)
    xtr, ytr, mask, xcand, hyper = _padded_problem(rng, 12)
    mu1, sig1, _ = model.gp_fit_predict(xtr, ytr, mask, xcand, hyper)

    xtr2, ytr2 = xtr.copy(), ytr.copy()
    xtr2[12:, :] = rng.uniform(size=(model.N_PAD - 12, model.D_FEAT))
    ytr2[12:] = 1e3  # wild garbage y under the mask
    mu2, sig2, _ = model.gp_fit_predict(xtr2, ytr2, mask, xcand, hyper)
    assert_allclose(np.asarray(mu1), np.asarray(mu2), rtol=1e-5, atol=1e-5)
    assert_allclose(np.asarray(sig1), np.asarray(sig2), rtol=1e-5, atol=1e-5)


def test_posterior_interpolates_training_points():
    """At the noise floor, mu(x_i) ~= y_i and sigma(x_i) small at history
    points (nv passed below the floor gets clamped to 1e-3 in-graph)."""
    rng = np.random.default_rng(5)
    n_real = 10
    xtr, ytr, mask, xcand, hyper = _padded_problem(rng, n_real, nv=1e-6)
    xcand[:n_real] = xtr[:n_real]
    mu, sigma, _ = (np.asarray(v) for v in model.gp_fit_predict(xtr, ytr, mask, xcand, hyper))
    assert_allclose(mu[:n_real], ytr[:n_real], rtol=0, atol=5e-3)
    assert (sigma[:n_real] < 0.05).all()


def test_prior_far_from_data():
    """Far from all history the posterior reverts to the prior (0, sv)."""
    rng = np.random.default_rng(6)
    xtr, ytr, mask, xcand, hyper = _padded_problem(rng, 8, ls=0.05)
    xcand[:] = 50.0  # far outside [0,1]^d
    mu, sigma, _ = (np.asarray(v) for v in model.gp_fit_predict(xtr, ytr, mask, xcand, hyper))
    assert_allclose(mu, np.zeros_like(mu), atol=1e-4)
    assert_allclose(sigma, np.ones_like(sigma), rtol=1e-3)


def test_sigma_nonnegative_and_bounded():
    rng = np.random.default_rng(7)
    xtr, ytr, mask, xcand, hyper = _padded_problem(rng, 40)
    _, sigma, _ = model.gp_fit_predict(xtr, ytr, mask, xcand, hyper)
    sigma = np.asarray(sigma)
    assert (sigma >= 0).all()
    assert (sigma <= np.sqrt(hyper[1]) + 1e-4).all()


def test_full_history_no_mask():
    """n_real == N_PAD exercises the no-padding path."""
    rng = np.random.default_rng(8)
    xtr, ytr, mask, xcand, hyper = _padded_problem(rng, model.N_PAD)
    mu, _, _ = (np.asarray(v) for v in model.gp_fit_predict(xtr, ytr, mask, xcand, hyper))
    mu_ref, _ = gp_posterior_ref(xtr, ytr, xcand, hyper[0], hyper[1], hyper[2])
    assert_allclose(mu, np.asarray(mu_ref), rtol=2e-3, atol=2e-3)


def test_acquisition_prefers_uncertainty():
    """With equal mu, higher sigma must score higher gain (exploration)."""
    gain_lo = smsego_gain_ref(0.5, 0.1, 1.0, 1.5)
    gain_hi = smsego_gain_ref(0.5, 0.9, 1.0, 1.5)
    assert gain_hi > gain_lo


def test_workload_mlp_shapes_and_simplex():
    rng = np.random.default_rng(9)
    args = [rng.normal(size=s.shape).astype(np.float32) * 0.1 for s in model.workload_example_args(8)]
    out = np.asarray(model.workload_mlp(*args))
    assert out.shape == (8, model.WORKLOAD_OUT)
    assert_allclose(out.sum(axis=1), np.ones(8), rtol=1e-5)
    assert (out >= 0).all()


@pytest.mark.parametrize("batch", model.WORKLOAD_BATCHES)
def test_workload_batches_lower(batch):
    args = model.workload_example_args(batch)
    assert args[0].shape == (batch, model.WORKLOAD_IN)
