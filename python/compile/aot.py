"""AOT pipeline: lower the L2 graphs to HLO *text* artifacts for Rust.

`make artifacts` runs this once; the Rust coordinator then loads the HLO
text via `HloModuleProto::from_text_file` and compiles it on the PJRT CPU
client. Python never runs again after this step.

Interchange format is HLO TEXT, not a serialized HloModuleProto: jax >= 0.5
emits protos with 64-bit instruction ids which xla_extension 0.5.1 rejects
(`proto.id() <= INT_MAX`); the text parser reassigns ids and round-trips
cleanly. See /opt/xla-example/README.md.

Outputs (under --out-dir, default ../artifacts):
  gp.hlo.txt            the fused GP fit+predict+acquisition graph (N_PAD=64)
  gp_n{N}.hlo.txt       larger-window GP variants (N_PAD in GP_VARIANTS)
  workload_b{B}.hlo.txt the real-workload MLP at each batch size B
  meta.json             the shape contract the Rust side asserts against
"""

from __future__ import annotations

import argparse
import json
import os

import jax
from jax._src.lib import xla_client as xc

from compile import model


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (the 0.5.1-safe path)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def gp_file(n_pad: int) -> str:
    """Artifact file per history capacity; the N_PAD=64 default keeps its
    historical name so existing deployments keep resolving."""
    return "gp.hlo.txt" if n_pad == model.N_PAD else f"gp_n{n_pad}.hlo.txt"


def lower_gp(n_pad: int = model.N_PAD) -> str:
    iters = model.cg_iters_for(n_pad)

    def fn(xtr, ytr, mask, xcand, hyper):
        return model.gp_fit_predict(xtr, ytr, mask, xcand, hyper, cg_iters=iters)

    lowered = jax.jit(fn).lower(*model.gp_example_args(n_pad=n_pad))
    return to_hlo_text(lowered)


def lower_workload(batch: int) -> str:
    def fn(*args):
        return (model.workload_mlp(*args),)

    lowered = jax.jit(fn).lower(*model.workload_example_args(batch))
    return to_hlo_text(lowered)


def build_meta() -> dict:
    return {
        "gp": {
            "n_pad": model.N_PAD,
            "d_feat": model.D_FEAT,
            "c_cand": model.C_CAND,
            "cg_iters": model.CG_ITERS,
            "inputs": ["xtr", "ytr", "mask", "xcand", "hyper"],
            "hyper": ["lengthscale", "signal_var", "noise_var", "acq_alpha", "y_best"],
            "outputs": ["mu", "sigma", "gain"],
            "file": "gp.hlo.txt",
            # Larger-window recompiles: same graph per capacity, variant
            # CG depth. The Rust loader (runtime/gp.rs load_for_window)
            # picks the smallest n_pad covering the requested window.
            "variants": [
                {
                    "n_pad": n,
                    "cg_iters": model.cg_iters_for(n),
                    "file": gp_file(n),
                }
                for n in model.GP_VARIANTS
            ],
        },
        "workload": {
            "batches": list(model.WORKLOAD_BATCHES),
            "d_in": model.WORKLOAD_IN,
            "d_hidden": model.WORKLOAD_HIDDEN,
            "d_out": model.WORKLOAD_OUT,
            "flops_per_example": model.workload_flops_per_example(),
            "file_pattern": "workload_b{batch}.hlo.txt",
        },
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default=os.path.join("..", "artifacts"))
    ap.add_argument(
        "--only",
        choices=["gp", "workload", "all"],
        default="all",
        help="restrict what gets lowered (for faster iteration)",
    )
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    written = []
    if args.only in ("gp", "all"):
        for n_pad in model.GP_VARIANTS:
            path = os.path.join(args.out_dir, gp_file(n_pad))
            text = lower_gp(n_pad)
            with open(path, "w") as f:
                f.write(text)
            written.append((path, len(text)))

    if args.only in ("workload", "all"):
        for batch in model.WORKLOAD_BATCHES:
            path = os.path.join(args.out_dir, f"workload_b{batch}.hlo.txt")
            text = lower_workload(batch)
            with open(path, "w") as f:
                f.write(text)
            written.append((path, len(text)))

    meta_path = os.path.join(args.out_dir, "meta.json")
    with open(meta_path, "w") as f:
        json.dump(build_meta(), f, indent=2)
        f.write("\n")
    written.append((meta_path, os.path.getsize(meta_path)))

    for path, size in written:
        print(f"wrote {size:>9} bytes  {path}")


if __name__ == "__main__":
    main()
