"""L2 — the Bayesian-optimization numeric graph (build-time JAX).

One jitted function, `gp_fit_predict`, does everything the Rust BO engine
needs per tuning iteration:

    fit   : solve (K + sigma_n^2 I) alpha = y        on the history
    predict: mu, sigma at C candidate configurations
    score : SMSego-style optimistic gain vs. the incumbent

It is lowered ONCE by aot.py to `artifacts/gp.hlo.txt` and executed from
Rust via PJRT on every BO iteration — Python is never on the tuning path.

Key constraints shaping the implementation:

  * Fixed shapes. PJRT executables are monomorphic, so the history is
    padded to N_PAD points with a {0,1} mask, candidates to C_CAND, and
    features to D_FEAT. Masked history rows are replaced by identity
    rows/cols in the kernel matrix (not a large-jitter hack — that would
    wreck CG conditioning) so they contribute exactly nothing.
  * No LAPACK. jax's `linalg.solve` lowers to LAPACK custom-calls on CPU
    which xla_extension 0.5.1 cannot execute. The solve is a
    fixed-iteration conjugate gradient over all right-hand sides at once
    (the y vector plus all C candidate kernel columns) — pure dot/while
    HLO. K is SPD with unit-scale diagonal, so CG_ITERS ~ 48 drives the
    residual to ~1e-6 for N_PAD = 64 (verified in python/tests/test_gp.py
    and again from Rust against the native-Rust exact GP).
  * The O(N*C*D) kernel matrices come from the L1 Pallas kernel
    (kernels/rbf.py), so the Pallas code is part of the same artifact.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from compile.kernels.rbf import rbf_kernel_matrix

# ---------------------------------------------------------------------------
# Artifact shape contract (mirrored in artifacts/meta.json for Rust).
# ---------------------------------------------------------------------------
N_PAD = 64      # max history points the GP conditions on
D_FEAT = 8      # feature dim: 5 tuning parameters, zero-padded to 8
C_CAND = 512    # candidate configurations scored per iteration
# Fixed CG iteration count. Perf-pass calibration (EXPERIMENTS.md §Perf):
# convergence at n = N_PAD = 64 depends strongly on the RBF lengthscale
# (larger ls => flatter kernel spectrum => worse conditioning). With the
# 1e-3 noise floor applied inside the graph (see gp_fit_predict):
#   ls=0.20 -> max|Δmu| ~2e-5 at 32 iters (5 seeds)
#   ls=0.25 -> ~3e-5 at 32 iters (5 seeds)
#   ls=0.35 -> up to 3e-1 — OUTSIDE the envelope (f32 CG cannot save a
#              near-singular K; neither could the original 48 iterations)
# The supported hyperparameter envelope for this artifact is therefore
# lengthscale <= 0.25; the BO engine runs at a fixed ls = 0.2. 32
# iterations covers the envelope with margin and cuts the dominant matmul
# cost 1.5x vs the original 48.
CG_ITERS = 32

# History-capacity variants the AOT pipeline emits (aot.py). The graph is
# monomorphic per capacity, so serving a larger conditioning window means
# compiling a larger artifact — the Rust loader picks the smallest variant
# whose n_pad covers the requested window (runtime/gp.rs).
GP_VARIANTS = (64, 128, 256)


def cg_iters_for(n_pad: int) -> int:
    """Fixed CG iteration count per history capacity.

    Larger K means a longer spectrum for CG to sweep; the counts below
    extend the n_pad=64 calibration above with the same ls<=0.25 envelope
    (iterations grow sublinearly in n because the 1e-3 noise floor caps
    the condition number).
    """
    calibrated = {64: CG_ITERS, 128: 48, 256: 64}
    if n_pad in calibrated:
        return calibrated[n_pad]
    # Uncalibrated capacity: scale conservatively from the nearest pin.
    return max(CG_ITERS, n_pad // 4)

# Batch sizes at which the real-workload MLP is AOT-compiled. The
# real-workload example tunes over this axis with *measured* throughput.
WORKLOAD_BATCHES = (1, 8, 32, 128)
WORKLOAD_IN = 64
WORKLOAD_HIDDEN = 256
WORKLOAD_OUT = 10


def _cg_solve(k: jax.Array, b: jax.Array, iters: int) -> jax.Array:
    """Batched conjugate gradient: solve k @ x = b for SPD k.

    k: (n, n), b: (n, r) — all r right-hand sides advance together; every
    op is a dot or elementwise, so the whole solve lowers to plain HLO.
    Per-RHS scalars (r_dot, alpha, beta) are kept as (1, r) rows.
    """
    x = jnp.zeros_like(b)
    r = b  # b - k @ 0
    p = r
    rs = jnp.sum(r * r, axis=0, keepdims=True)  # (1, r)

    def body(_, state):
        x, r, p, rs = state
        kp = k @ p
        denom = jnp.sum(p * kp, axis=0, keepdims=True)
        alpha = rs / jnp.maximum(denom, 1e-30)
        x = x + alpha * p
        r = r - alpha * kp
        rs_new = jnp.sum(r * r, axis=0, keepdims=True)
        beta = rs_new / jnp.maximum(rs, 1e-30)
        p = r + beta * p
        return x, r, p, rs_new

    x, _, _, _ = jax.lax.fori_loop(0, iters, body, (x, r, p, rs))
    return x


def gp_fit_predict(xtr, ytr, mask, xcand, hyper, cg_iters: int = CG_ITERS):
    """Fit the GP on the (masked) history and score the candidates.

    Shapes are taken from the arguments, so one definition serves every
    GP_VARIANTS capacity — `aot.py` lowers it once per (n_pad, cg_iters)
    pair. Args (all float32, n_pad = xtr.shape[0]):
      xtr:   (n_pad, D_FEAT)  history configurations, normalised to [0,1].
      ytr:   (n_pad,)         standardised objective values; 0 where masked.
      mask:  (n_pad,)         1.0 = real history point, 0.0 = padding.
      xcand: (C_CAND, D_FEAT) candidate configurations.
      hyper: (5,)             [lengthscale, signal_var, noise_var,
                               acq_alpha, y_best].
    Returns:
      mu    (C_CAND,) posterior mean,
      sigma (C_CAND,) posterior stddev,
      gain  (C_CAND,) SMSego optimistic gain (mu + alpha*sigma) - y_best.
    """
    ls, sv, nv, acq_alpha, y_best = (hyper[i] for i in range(5))
    # Conditioning floor: kappa(K) grows explosively for smooth kernels at
    # tiny noise (the fixed-iteration CG would silently diverge — see the
    # EXPERIMENTS.md §Perf envelope note). Real throughput measurements
    # carry >= 1% run-to-run noise, so a 1e-3 variance floor on the
    # standardised y is statistically honest and keeps CG_ITERS sufficient
    # across the whole supported lengthscale range.
    nv = jnp.maximum(nv, 1e-3)

    # L1 Pallas kernel: train/train and cand/train RBF matrices.
    ktt = rbf_kernel_matrix(xtr, xtr, ls, sv)          # (N, N)
    kct = rbf_kernel_matrix(xcand, xtr, ls, sv)        # (C, N)

    # Mask padding: masked rows/cols of K become identity rows/cols, and
    # masked candidate columns vanish. K stays SPD and well-conditioned.
    m2 = mask[:, None] * mask[None, :]
    eye = jnp.eye(xtr.shape[0], dtype=jnp.float32)
    k = ktt * m2 + eye * (nv * mask + (1.0 - mask))
    kct = kct * mask[None, :]

    # One batched CG solve for [y | Kct^T]  ->  [alpha | Z].
    rhs = jnp.concatenate([(ytr * mask)[:, None], kct.T], axis=1)  # (N, C+1)
    sol = _cg_solve(k, rhs, cg_iters)
    alpha_vec = sol[:, 0]                                          # (N,)
    z = sol[:, 1:]                                                 # (N, C)

    mu = kct @ alpha_vec                                           # (C,)
    var = sv - jnp.sum(kct * z.T, axis=1)
    sigma = jnp.sqrt(jnp.maximum(var, 1e-12))
    gain = (mu + acq_alpha * sigma) - y_best
    return mu, sigma, gain


def gp_example_args(n_pad: int = N_PAD, c_cand: int = C_CAND):
    """ShapeDtypeStructs matching gp_fit_predict's signature (for AOT)."""
    f32 = jnp.float32
    return (
        jax.ShapeDtypeStruct((n_pad, D_FEAT), f32),
        jax.ShapeDtypeStruct((n_pad,), f32),
        jax.ShapeDtypeStruct((n_pad,), f32),
        jax.ShapeDtypeStruct((c_cand, D_FEAT), f32),
        jax.ShapeDtypeStruct((5,), f32),
    )


# ---------------------------------------------------------------------------
# Real tunable workload: a small NCF-style MLP, AOT-compiled per batch size.
# The Rust real-workload evaluator times actual PJRT executions of these —
# a genuinely measurable system-under-test for the end-to-end example.
# ---------------------------------------------------------------------------


def workload_mlp(x, w1, b1, w2, b2, w3, b3):
    """3-layer ReLU MLP with a softmax head: (b, 64) -> (b, 10)."""
    h = jax.nn.relu(x @ w1 + b1)
    h = jax.nn.relu(h @ w2 + b2)
    logits = h @ w3 + b3
    return jax.nn.softmax(logits, axis=-1)


def workload_example_args(batch: int):
    f32 = jnp.float32
    i, h, o = WORKLOAD_IN, WORKLOAD_HIDDEN, WORKLOAD_OUT
    return (
        jax.ShapeDtypeStruct((batch, i), f32),
        jax.ShapeDtypeStruct((i, h), f32),
        jax.ShapeDtypeStruct((h,), f32),
        jax.ShapeDtypeStruct((h, h), f32),
        jax.ShapeDtypeStruct((h,), f32),
        jax.ShapeDtypeStruct((h, o), f32),
        jax.ShapeDtypeStruct((o,), f32),
    )


def workload_flops_per_example() -> int:
    """Dense-layer multiply-add FLOPs per input example (2 * m*n per GEMV)."""
    i, h, o = WORKLOAD_IN, WORKLOAD_HIDDEN, WORKLOAD_OUT
    return 2 * (i * h + h * h + h * o)
