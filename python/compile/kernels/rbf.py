"""L1 — Pallas RBF kernel-matrix kernel.

The Gaussian-process surrogate at the heart of the Bayesian-optimization
engine spends its O(n·m·d) inner loop building RBF kernel matrices

    K[i, j] = variance * exp(-0.5 * ||a_i - b_j||^2 / lengthscale^2)

This module implements that computation as a tiled Pallas kernel. It is
invoked from the L2 GP graph (python/compile/model.py) so that it lowers
into the single AOT HLO artifact executed by the Rust coordinator.

TPU-idiomatic structure (see DESIGN.md §Hardware-Adaptation):
  * the (n, m) output is tiled into (TILE_N, TILE_M) blocks; BlockSpec
    expresses the HBM->VMEM schedule,
  * the squared distance uses the matmul form ||a||^2 + ||b||^2 - 2 a.b^T
    so the dominant term maps onto the MXU systolic array,
  * the feature dimension d stays resident in VMEM (d is small for this
    workload: 5 tuning parameters padded to 8).

interpret=True is mandatory on this image: real TPU lowering emits a Mosaic
custom-call the CPU PJRT plugin cannot execute.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default tile sizes. 64x64 keeps the VMEM footprint per grid step at
# (TILE_N + TILE_M) * d * 4 + TILE_N * TILE_M * 4 bytes ~= 20 KiB for d=8,
# far below the ~16 MiB VMEM budget; larger tiles would raise MXU
# utilisation for big n,m but n,m <= 512 in this system.
TILE_N = 64
TILE_M = 64


def _rbf_block_kernel(a_ref, b_ref, ls2_ref, var_ref, out_ref):
    """Compute one (TILE_N, TILE_M) block of the RBF kernel matrix.

    a_ref:   (TILE_N, d) block of the left point set.
    b_ref:   (TILE_M, d) block of the right point set.
    ls2_ref: (1, 1) squared lengthscale.
    var_ref: (1, 1) signal variance.
    out_ref: (TILE_N, TILE_M) output block.
    """
    a = a_ref[...]
    b = b_ref[...]
    # ||a_i - b_j||^2 = ||a_i||^2 + ||b_j||^2 - 2 a_i . b_j  (MXU-friendly).
    a2 = jnp.sum(a * a, axis=1, keepdims=True)            # (TILE_N, 1)
    b2 = jnp.sum(b * b, axis=1, keepdims=True).T          # (1, TILE_M)
    cross = jax.lax.dot_general(
        a,
        b,
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )                                                      # (TILE_N, TILE_M)
    sq = a2 + b2 - 2.0 * cross
    # Floating-point cancellation can push tiny distances negative.
    sq = jnp.maximum(sq, 0.0)
    ls2 = ls2_ref[0, 0]
    var = var_ref[0, 0]
    out_ref[...] = var * jnp.exp(-0.5 * sq / ls2)


def _ceil_to(x: int, tile: int) -> int:
    return ((x + tile - 1) // tile) * tile


@functools.partial(jax.jit, static_argnames=("tile_n", "tile_m", "interpret"))
def rbf_kernel_matrix(
    a: jax.Array,
    b: jax.Array,
    lengthscale: jax.Array | float,
    variance: jax.Array | float,
    *,
    tile_n: int = TILE_N,
    tile_m: int = TILE_M,
    interpret: bool = True,
) -> jax.Array:
    """RBF (squared-exponential) kernel matrix via the Pallas kernel.

    a: (n, d) float32, b: (m, d) float32. Returns (n, m) float32 with
    K[i, j] = variance * exp(-0.5 * ||a_i - b_j||^2 / lengthscale^2).

    Shapes that are not multiples of the tile are zero-padded; the padding
    rows/cols are sliced away from the result, so any (n, m, d) works.
    """
    a = jnp.asarray(a, jnp.float32)
    b = jnp.asarray(b, jnp.float32)
    if a.ndim != 2 or b.ndim != 2:
        raise ValueError(f"expected 2-D point sets, got {a.shape} and {b.shape}")
    if a.shape[1] != b.shape[1]:
        raise ValueError(f"feature dims differ: {a.shape[1]} vs {b.shape[1]}")
    n, d = a.shape
    m = b.shape[0]
    tile_n = min(tile_n, _ceil_to(n, 8))
    tile_m = min(tile_m, _ceil_to(m, 8))

    np_, mp = _ceil_to(n, tile_n), _ceil_to(m, tile_m)
    a_pad = jnp.pad(a, ((0, np_ - n), (0, 0)))
    b_pad = jnp.pad(b, ((0, mp - m), (0, 0)))
    ls2 = jnp.asarray(lengthscale, jnp.float32).reshape(1, 1) ** 2
    var = jnp.asarray(variance, jnp.float32).reshape(1, 1)

    grid = (np_ // tile_n, mp // tile_m)
    out = pl.pallas_call(
        _rbf_block_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_n, d), lambda i, j: (i, 0)),
            pl.BlockSpec((tile_m, d), lambda i, j: (j, 0)),
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((tile_n, tile_m), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((np_, mp), jnp.float32),
        interpret=interpret,
    )(a_pad, b_pad, ls2, var)
    return out[:n, :m]


def vmem_footprint_bytes(tile_n: int = TILE_N, tile_m: int = TILE_M, d: int = 8) -> int:
    """Estimated VMEM bytes resident per grid step (see DESIGN.md §Perf)."""
    return 4 * (tile_n * d + tile_m * d + tile_n * tile_m + 2)


def mxu_flops_per_block(tile_n: int = TILE_N, tile_m: int = TILE_M, d: int = 8) -> int:
    """MXU (matmul) FLOPs per block — the 2*n*m*d cross term dominates."""
    return 2 * tile_n * tile_m * d
