"""Pure-jnp oracles for the Pallas kernels and the L2 GP graph.

Everything in this file is the *reference* implementation: simple,
obviously-correct jnp code with no Pallas, no tiling, no padding tricks.
pytest compares the production kernels against these (see python/tests/).
"""

from __future__ import annotations

import jax.numpy as jnp


def rbf_kernel_matrix_ref(a, b, lengthscale, variance):
    """K[i, j] = variance * exp(-0.5 * ||a_i - b_j||^2 / lengthscale^2)."""
    a = jnp.asarray(a, jnp.float32)
    b = jnp.asarray(b, jnp.float32)
    diff = a[:, None, :] - b[None, :, :]
    sq = jnp.sum(diff * diff, axis=-1)
    return variance * jnp.exp(-0.5 * sq / (lengthscale**2))


def gp_posterior_ref(xtr, ytr, xcand, lengthscale, signal_var, noise_var):
    """Exact GP posterior (dense solve) — oracle for the CG-based L2 graph.

    Returns (mu, var) at the candidate points for a zero-mean GP with RBF
    kernel and iid observation noise.
    """
    xtr = jnp.asarray(xtr, jnp.float32)
    ytr = jnp.asarray(ytr, jnp.float32)
    xcand = jnp.asarray(xcand, jnp.float32)
    n = xtr.shape[0]
    k = rbf_kernel_matrix_ref(xtr, xtr, lengthscale, signal_var)
    k = k + noise_var * jnp.eye(n, dtype=jnp.float32)
    kc = rbf_kernel_matrix_ref(xcand, xtr, lengthscale, signal_var)
    sol = jnp.linalg.solve(k, jnp.concatenate([ytr[:, None], kc.T], axis=1))
    alpha = sol[:, 0]
    z = sol[:, 1:]
    mu = kc @ alpha
    var = signal_var - jnp.sum(kc * z.T, axis=1)
    return mu, jnp.maximum(var, 1e-12)


def smsego_gain_ref(mu, sigma, y_best, alpha):
    """SMSego-style optimistic-gain acquisition (maximisation form)."""
    return (mu + alpha * sigma) - y_best
